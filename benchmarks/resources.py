"""Analytic per-client resource model — re-export.

The model itself lives in ``repro.roofline.client_costs`` (moved so the
trace CLI and the resource observatory, which run with only ``src`` on
the path, can price analytic columns next to measured ones); this module
keeps the historical ``benchmarks.resources`` import surface working for
the bench driver and tests. The analytic table is no longer a standalone
script: ``python -m benchmarks.run --only resources`` runs it as a
schema-validated bench suite (analytic vs measured columns,
``results/resources_bench.json``).
"""
from __future__ import annotations

from repro.roofline.client_costs import (  # noqa: F401
    BYTES_F32, PAPER_MULT, SCHEDULE_NAMES, VitCosts, build_ssl_param_tree,
    flops_per_sample_round, memory_bytes, schedule_costs, vit_costs)
