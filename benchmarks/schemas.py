"""Schemas for the JSON documents the benchmarks write under results/.

A stale results file once sketched a fleet-simulator schema whose code
never landed; to keep bench JSON from silently drifting away from what
the code emits again, the writer (``benchmarks.run``) and a tier-1 test
(``tests/test_simulation.py``) both validate against the single
definition here. The validators return a list of human-readable problems
(empty = valid) instead of raising, so callers can report every issue at
once.

Two documents are covered: the fleet-simulation bench
(``validate_simulation_bench``) and the wire-transport bench
(``validate_transport_bench`` — per-schedule pack/unpack throughput for
both wire engines plus one codec-throughput row per codec).
"""
from __future__ import annotations

from typing import Any, Dict, List

# field -> allowed types; a tuple means any of them. ``wall_clock_to_
# target_s`` is None when the run never reached the target loss.
SIMULATION_ROW_SCHEMA: Dict[str, Any] = {
    "schedule": str,
    "fleet": str,
    "policy": str,
    "rounds": int,
    "clients": int,
    "clients_per_round": int,
    "target_loss": float,
    "final_loss": float,
    "wall_clock_to_target_s": (float, type(None)),
    "total_wall_clock_s": float,
    "device_seconds": float,
    "energy_j": float,
    "dropped_client_rounds": int,
}

SIMULATION_TOP_KEYS = ("bench", "config", "rows")


def _check_row(i: int, row: Any, errors: List[str]):
    if not isinstance(row, dict):
        errors.append(f"rows[{i}]: expected object, got {type(row).__name__}")
        return
    for field, types in SIMULATION_ROW_SCHEMA.items():
        if field not in row:
            errors.append(f"rows[{i}]: missing field '{field}'")
            continue
        tt = types if isinstance(types, tuple) else (types,)
        v = row[field]
        # bool is an int subclass — reject it where int is expected
        ok = isinstance(v, tt) and not (isinstance(v, bool)
                                        and bool not in tt)
        if not ok:
            errors.append(f"rows[{i}].{field}: expected "
                          f"{'/'.join(t.__name__ for t in tt)}, "
                          f"got {type(v).__name__} ({v!r})")
    for field in row:
        if field not in SIMULATION_ROW_SCHEMA:
            errors.append(f"rows[{i}]: unknown field '{field}' "
                          f"(update benchmarks/schemas.py)")


def validate_simulation_bench(doc: Any) -> List[str]:
    """Validate a simulation-bench document; returns a list of problems."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"top level: expected object, got {type(doc).__name__}"]
    for k in SIMULATION_TOP_KEYS:
        if k not in doc:
            errors.append(f"top level: missing key '{k}'")
    if doc.get("bench") != "simulation":
        errors.append(f"bench: expected 'simulation', "
                      f"got {doc.get('bench')!r}")
    rows = doc.get("rows", [])
    if not isinstance(rows, list) or not rows:
        errors.append("rows: expected a non-empty list")
        return errors
    for i, row in enumerate(rows):
        _check_row(i, row, errors)
    return errors


# ---------------------------------------------------------------------------
# transport bench
# ---------------------------------------------------------------------------
TRANSPORT_ENGINES = ("xla", "pallas")

# per-schedule row: pack/unpack GB/s per wire engine + per-codec round
# wire size / compression ratio (sizes are analytic, not timed).
TRANSPORT_ROW_SCHEMA: Dict[str, Any] = {
    "schedule": str,
    "upload_payload_mb": float,
    "pack_gbps": dict,
    "unpack_gbps": dict,
    "pack_speedup": float,
    "unpack_speedup": float,
    "codecs": dict,
}

# one row per codec, timed on the largest (e2e) upload payload.
TRANSPORT_CODEC_ROW_SCHEMA: Dict[str, Any] = {
    "codec": str,
    "payload_mb": float,
    "encode_gbps": dict,
    "decode_gbps": dict,
}

TRANSPORT_TOP_KEYS = ("bench", "config", "rows", "codec_rows")


def _check_engine_map(where: str, v: Any, errors: List[str]):
    if not isinstance(v, dict):
        return
    for eng in TRANSPORT_ENGINES:
        if eng not in v:
            errors.append(f"{where}: missing engine '{eng}'")
        elif not isinstance(v.get(eng), float):
            errors.append(f"{where}.{eng}: expected float, "
                          f"got {type(v[eng]).__name__}")
    for eng in v:
        if eng not in TRANSPORT_ENGINES:
            errors.append(f"{where}: unknown engine '{eng}'")


def _check_fields(where: str, row: Any, schema: Dict[str, Any],
                  errors: List[str]):
    if not isinstance(row, dict):
        errors.append(f"{where}: expected object, got {type(row).__name__}")
        return
    for field, types in schema.items():
        if field not in row:
            errors.append(f"{where}: missing field '{field}'")
            continue
        tt = types if isinstance(types, tuple) else (types,)
        v = row[field]
        ok = isinstance(v, tt) and not (isinstance(v, bool)
                                        and bool not in tt)
        if not ok:
            errors.append(f"{where}.{field}: expected "
                          f"{'/'.join(t.__name__ for t in tt)}, "
                          f"got {type(v).__name__} ({v!r})")
    for field in row:
        if field not in schema:
            errors.append(f"{where}: unknown field '{field}' "
                          f"(update benchmarks/schemas.py)")


def validate_transport_bench(doc: Any) -> List[str]:
    """Validate a transport-bench document; returns a list of problems."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"top level: expected object, got {type(doc).__name__}"]
    for k in TRANSPORT_TOP_KEYS:
        if k not in doc:
            errors.append(f"top level: missing key '{k}'")
    if doc.get("bench") != "transport":
        errors.append(f"bench: expected 'transport', "
                      f"got {doc.get('bench')!r}")
    rows = doc.get("rows", [])
    if not isinstance(rows, list) or not rows:
        errors.append("rows: expected a non-empty list")
        return errors
    for i, row in enumerate(rows):
        _check_fields(f"rows[{i}]", row, TRANSPORT_ROW_SCHEMA, errors)
        if isinstance(row, dict):
            for f in ("pack_gbps", "unpack_gbps"):
                _check_engine_map(f"rows[{i}].{f}", row.get(f), errors)
            codecs = row.get("codecs")
            if isinstance(codecs, dict):
                for name, c in codecs.items():
                    _check_fields(f"rows[{i}].codecs[{name}]", c,
                                  {"round_wire_mb": float, "ratio": float},
                                  errors)
    crows = doc.get("codec_rows", [])
    if not isinstance(crows, list) or not crows:
        errors.append("codec_rows: expected a non-empty list")
        return errors
    for i, row in enumerate(crows):
        _check_fields(f"codec_rows[{i}]", row, TRANSPORT_CODEC_ROW_SCHEMA,
                      errors)
        if isinstance(row, dict):
            for f in ("encode_gbps", "decode_gbps"):
                _check_engine_map(f"codec_rows[{i}].{f}", row.get(f),
                                  errors)
    return errors
