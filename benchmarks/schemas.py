"""Schemas for the JSON documents the benchmarks write under results/.

A stale results file once sketched a fleet-simulator schema whose code
never landed; to keep bench JSON from silently drifting away from what
the code emits again, the writer (``benchmarks.run``) and a tier-1 test
(``tests/test_simulation.py``) both validate against the single
definition here. The validators return a list of human-readable problems
(empty = valid) instead of raising, so callers can report every issue at
once.

Document families covered: the fleet-simulation bench
(``validate_simulation_bench``), the wire-transport bench
(``validate_transport_bench`` — per-schedule pack/unpack throughput for
both wire engines plus one codec-throughput row per codec), the privacy
bench (``validate_privacy_bench`` — DP/secure-agg utility and overhead
per schedule x codec x mode), the measured-resources bench
(``validate_resources_bench`` — XLA cost/memory analysis vs the analytic
roofline per engine x schedule), the health report the driver exports
(``validate_health_report``), and the two observability exports from
``repro.obs`` — the JSONL span stream (``validate_trace_jsonl``) and the
Chrome ``trace_event`` document (``validate_chrome_trace``) that
Perfetto / chrome://tracing loads — plus the flattened metrics CSV
(``validate_metrics_csv``).

Every bench document additionally carries the shared provenance header
from ``benchmarks.provenance`` (git commit, seed, jax/jaxlib versions,
platform, timestamp) so results files stay comparable across PRs;
``_check_provenance`` enforces it in each bench validator.
"""
from __future__ import annotations

from typing import Any, Dict, List

# field -> allowed types; a tuple means any of them. ``wall_clock_to_
# target_s`` is None when the run never reached the target loss.
SIMULATION_ROW_SCHEMA: Dict[str, Any] = {
    "schedule": str,
    "fleet": str,
    "policy": str,
    "rounds": int,
    "clients": int,
    "clients_per_round": int,
    "target_loss": float,
    "final_loss": float,
    "wall_clock_to_target_s": (float, type(None)),
    "total_wall_clock_s": float,
    "device_seconds": float,
    "energy_j": float,
    "dropped_client_rounds": int,
}

SIMULATION_TOP_KEYS = ("bench", "config", "rows", "provenance")

# the shared header benchmarks.provenance stamps on every bench doc
PROVENANCE_SCHEMA: Dict[str, Any] = {
    "version": int,
    "git_commit": str,
    "seed": (int, type(None)),
    "jax": str,
    "jaxlib": str,
    "backend": str,
    "platform": str,
    "python": str,
    "timestamp": str,
}


def _check_provenance(doc: Any, errors: List[str]):
    if not isinstance(doc, dict):
        return
    prov = doc.get("provenance")
    if prov is None:
        errors.append("provenance: missing (stamp with "
                      "benchmarks.provenance.provenance())")
        return
    _check_fields("provenance", prov, PROVENANCE_SCHEMA, errors)

# optional per-row extras: newer bench runs embed the versioned
# ``FLHistory.to_dict()`` round-trip form; older checked-in artifacts
# predate it.
SIMULATION_ROW_OPTIONAL: Dict[str, Any] = {
    "history": dict,
}


def _check_row(i: int, row: Any, errors: List[str]):
    if not isinstance(row, dict):
        errors.append(f"rows[{i}]: expected object, got {type(row).__name__}")
        return
    for field, types in SIMULATION_ROW_SCHEMA.items():
        if field not in row:
            errors.append(f"rows[{i}]: missing field '{field}'")
            continue
        tt = types if isinstance(types, tuple) else (types,)
        v = row[field]
        # bool is an int subclass — reject it where int is expected
        ok = isinstance(v, tt) and not (isinstance(v, bool)
                                        and bool not in tt)
        if not ok:
            errors.append(f"rows[{i}].{field}: expected "
                          f"{'/'.join(t.__name__ for t in tt)}, "
                          f"got {type(v).__name__} ({v!r})")
    for field, types in SIMULATION_ROW_OPTIONAL.items():
        if field in row and not isinstance(row[field], types):
            errors.append(f"rows[{i}].{field}: expected "
                          f"{types.__name__}, "
                          f"got {type(row[field]).__name__}")
    for field in row:
        if field not in SIMULATION_ROW_SCHEMA \
                and field not in SIMULATION_ROW_OPTIONAL:
            errors.append(f"rows[{i}]: unknown field '{field}' "
                          f"(update benchmarks/schemas.py)")


def validate_simulation_bench(doc: Any) -> List[str]:
    """Validate a simulation-bench document; returns a list of problems."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"top level: expected object, got {type(doc).__name__}"]
    for k in SIMULATION_TOP_KEYS:
        if k not in doc:
            errors.append(f"top level: missing key '{k}'")
    if doc.get("bench") != "simulation":
        errors.append(f"bench: expected 'simulation', "
                      f"got {doc.get('bench')!r}")
    _check_provenance(doc, errors)
    rows = doc.get("rows", [])
    if not isinstance(rows, list) or not rows:
        errors.append("rows: expected a non-empty list")
        return errors
    for i, row in enumerate(rows):
        _check_row(i, row, errors)
    return errors


# ---------------------------------------------------------------------------
# transport bench
# ---------------------------------------------------------------------------
TRANSPORT_ENGINES = ("xla", "pallas")

# per-schedule row: pack/unpack GB/s per wire engine + per-codec round
# wire size / compression ratio (sizes are analytic, not timed).
TRANSPORT_ROW_SCHEMA: Dict[str, Any] = {
    "schedule": str,
    "upload_payload_mb": float,
    "pack_gbps": dict,
    "unpack_gbps": dict,
    "pack_speedup": float,
    "unpack_speedup": float,
    "codecs": dict,
}

# one row per codec, timed on the largest (e2e) upload payload.
TRANSPORT_CODEC_ROW_SCHEMA: Dict[str, Any] = {
    "codec": str,
    "payload_mb": float,
    "encode_gbps": dict,
    "decode_gbps": dict,
}

TRANSPORT_TOP_KEYS = ("bench", "config", "rows", "codec_rows",
                      "provenance")


def _check_engine_map(where: str, v: Any, errors: List[str]):
    if not isinstance(v, dict):
        return
    for eng in TRANSPORT_ENGINES:
        if eng not in v:
            errors.append(f"{where}: missing engine '{eng}'")
        elif not isinstance(v.get(eng), float):
            errors.append(f"{where}.{eng}: expected float, "
                          f"got {type(v[eng]).__name__}")
    for eng in v:
        if eng not in TRANSPORT_ENGINES:
            errors.append(f"{where}: unknown engine '{eng}'")


def _check_fields(where: str, row: Any, schema: Dict[str, Any],
                  errors: List[str]):
    if not isinstance(row, dict):
        errors.append(f"{where}: expected object, got {type(row).__name__}")
        return
    for field, types in schema.items():
        if field not in row:
            errors.append(f"{where}: missing field '{field}'")
            continue
        tt = types if isinstance(types, tuple) else (types,)
        v = row[field]
        ok = isinstance(v, tt) and not (isinstance(v, bool)
                                        and bool not in tt)
        if not ok:
            errors.append(f"{where}.{field}: expected "
                          f"{'/'.join(t.__name__ for t in tt)}, "
                          f"got {type(v).__name__} ({v!r})")
    for field in row:
        if field not in schema:
            errors.append(f"{where}: unknown field '{field}' "
                          f"(update benchmarks/schemas.py)")


def validate_transport_bench(doc: Any) -> List[str]:
    """Validate a transport-bench document; returns a list of problems."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"top level: expected object, got {type(doc).__name__}"]
    for k in TRANSPORT_TOP_KEYS:
        if k not in doc:
            errors.append(f"top level: missing key '{k}'")
    if doc.get("bench") != "transport":
        errors.append(f"bench: expected 'transport', "
                      f"got {doc.get('bench')!r}")
    _check_provenance(doc, errors)
    rows = doc.get("rows", [])
    if not isinstance(rows, list) or not rows:
        errors.append("rows: expected a non-empty list")
        return errors
    for i, row in enumerate(rows):
        _check_fields(f"rows[{i}]", row, TRANSPORT_ROW_SCHEMA, errors)
        if isinstance(row, dict):
            for f in ("pack_gbps", "unpack_gbps"):
                _check_engine_map(f"rows[{i}].{f}", row.get(f), errors)
            codecs = row.get("codecs")
            if isinstance(codecs, dict):
                for name, c in codecs.items():
                    _check_fields(f"rows[{i}].codecs[{name}]", c,
                                  {"round_wire_mb": float, "ratio": float},
                                  errors)
    crows = doc.get("codec_rows", [])
    if not isinstance(crows, list) or not crows:
        errors.append("codec_rows: expected a non-empty list")
        return errors
    for i, row in enumerate(crows):
        _check_fields(f"codec_rows[{i}]", row, TRANSPORT_CODEC_ROW_SCHEMA,
                      errors)
        if isinstance(row, dict):
            for f in ("encode_gbps", "decode_gbps"):
                _check_engine_map(f"codec_rows[{i}].{f}", row.get(f),
                                  errors)
    return errors


# ---------------------------------------------------------------------------
# privacy bench
# ---------------------------------------------------------------------------
# one row per schedule x codec x privacy mode: utility delta vs the
# unprotected baseline, wire cost (codec wire + secure-agg mask overhead)
# and throughput cost. ``epsilon``/``clip_fraction`` are None for modes
# without DP (baseline / secure-agg only).
PRIVACY_ROW_SCHEMA: Dict[str, Any] = {
    "schedule": str,
    "codec": str,
    "dp": bool,
    "secure_agg": bool,
    "rounds": int,
    "clients": int,
    "final_loss": float,
    "utility_delta": float,
    "epsilon": (float, type(None)),
    "clip_fraction": (float, type(None)),
    "wire_mb": float,
    "mask_overhead_mb": float,
    "rounds_per_sec": float,
    "slowdown": float,
}

PRIVACY_TOP_KEYS = ("bench", "config", "rows", "provenance")


def validate_privacy_bench(doc: Any) -> List[str]:
    """Validate a privacy-bench document; returns a list of problems."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"top level: expected object, got {type(doc).__name__}"]
    for k in PRIVACY_TOP_KEYS:
        if k not in doc:
            errors.append(f"top level: missing key '{k}'")
    if doc.get("bench") != "privacy":
        errors.append(f"bench: expected 'privacy', "
                      f"got {doc.get('bench')!r}")
    _check_provenance(doc, errors)
    rows = doc.get("rows", [])
    if not isinstance(rows, list) or not rows:
        errors.append("rows: expected a non-empty list")
        return errors
    for i, row in enumerate(rows):
        _check_fields(f"rows[{i}]", row, PRIVACY_ROW_SCHEMA, errors)
        if isinstance(row, dict):
            # DP rows must report their accounting; non-DP rows must not
            # fabricate one
            if row.get("dp") is True and row.get("epsilon") is None:
                errors.append(f"rows[{i}].epsilon: required when dp=true")
            if row.get("dp") is False and row.get("epsilon") is not None:
                errors.append(f"rows[{i}].epsilon: must be null when "
                              f"dp=false")
    return errors


# ---------------------------------------------------------------------------
# measured-resources bench
# ---------------------------------------------------------------------------
# one row per engine x schedule, the measure_schedule/paper_table shape:
# measured FLOPs + peak memory from the compiled XLA round programs
# (``peak_memory``/``argument_bytes``/... are None on flops-only runs),
# analytic predictions at the same config, full-scale comm, and
# reduction multipliers against the engine's own e2e row.
_NUM_OR_NONE = (int, float, type(None))

RESOURCES_ROW_SCHEMA: Dict[str, Any] = {
    "engine": str,
    "schedule": str,
    "num_layers": int,
    "batch_size": int,
    "rounds": int,
    "local_epochs": int,
    "clients": int,
    "stages": list,
    "flops_total": (int, float),
    "analytic_flops_total": (int, float),
    "analytic_peak_memory": (int, float),
    "program_peak_analytic": (int, float),
    "peak_memory": _NUM_OR_NONE,
    "argument_bytes": _NUM_OR_NONE,
    "output_bytes": _NUM_OR_NONE,
    "temp_bytes": _NUM_OR_NONE,
    "comm_bytes": int,
    "comm_ratio": float,
    "analytic_flops_ratio": float,
    "analytic_memory_ratio": float,
    "flops_ratio": float,
    "memory_ratio": (float, type(None)),
}

RESOURCES_STAGE_SCHEMA: Dict[str, Any] = {
    "sub_layers": int,
    "active_from": int,
    "align": bool,
    "depth_dropout": float,
    "rounds": int,
    "flops_per_sample": (int, float),
    "analytic_flops_per_sample": (int, float),
    "analytic_memory_bytes": (int, float),
}

RESOURCES_TOP_KEYS = ("bench", "config", "rows", "provenance")


def validate_resources_bench(doc: Any) -> List[str]:
    """Validate a measured-resources bench document; returns a list of
    problems. Beyond shape, the measured-vs-analytic tolerances from the
    document's own config are enforced — a results file whose measured
    FLOPs drifted outside ``flops_rtol`` of the analytic roofline is
    invalid, not merely different."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"top level: expected object, got {type(doc).__name__}"]
    for k in RESOURCES_TOP_KEYS:
        if k not in doc:
            errors.append(f"top level: missing key '{k}'")
    if doc.get("bench") != "resources":
        errors.append(f"bench: expected 'resources', "
                      f"got {doc.get('bench')!r}")
    _check_provenance(doc, errors)
    cfg = doc.get("config", {})
    tol = cfg.get("tolerances", {}) if isinstance(cfg, dict) else {}
    flops_rtol = tol.get("flops_rtol")
    memory_factor = tol.get("memory_factor")
    rows = doc.get("rows", [])
    if not isinstance(rows, list) or not rows:
        errors.append("rows: expected a non-empty list")
        return errors
    for i, row in enumerate(rows):
        _check_fields(f"rows[{i}]", row, RESOURCES_ROW_SCHEMA, errors)
        if not isinstance(row, dict):
            continue
        for j, st in enumerate(row.get("stages") or []):
            _check_fields(f"rows[{i}].stages[{j}]", st,
                          RESOURCES_STAGE_SCHEMA, errors)
        meas, an = row.get("flops_total"), row.get("analytic_flops_total")
        if isinstance(flops_rtol, float) and isinstance(meas, (int, float)) \
                and isinstance(an, (int, float)) and an > 0:
            if abs(meas / an - 1.0) > flops_rtol:
                errors.append(
                    f"rows[{i}].flops_total: measured/analytic "
                    f"{meas / an:.3f} outside +-{flops_rtol:.0%}")
        peak = row.get("peak_memory")
        pan = row.get("program_peak_analytic")
        if isinstance(memory_factor, float) \
                and isinstance(peak, (int, float)) \
                and isinstance(pan, (int, float)) and pan > 0:
            ratio = peak / pan
            if ratio > memory_factor or ratio < 1.0 / memory_factor:
                errors.append(
                    f"rows[{i}].peak_memory: measured/analytic "
                    f"{ratio:.3f} outside [1/{memory_factor:g}, "
                    f"{memory_factor:g}]")
    return errors


# ---------------------------------------------------------------------------
# health report (repro.obs.health exporter)
# ---------------------------------------------------------------------------
from repro.obs.health import (ALERT_KINDS, ALERT_LEVELS,  # noqa: E402
                              HEALTH_VERSION)

HEALTH_ALERT_SCHEMA: Dict[str, Any] = {
    "round": int,
    "kind": str,
    "level": str,
    "value": (int, float, type(None)),
    "message": str,
}

HEALTH_TOP_KEYS = ("version", "rounds_observed", "fatal", "halted",
                   "counts", "alerts", "config")


def validate_health_report(doc: Any) -> List[str]:
    """Validate a ``health.json`` document as written by
    ``repro.obs.health.write_health_json``; returns a list of problems."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"top level: expected object, got {type(doc).__name__}"]
    for k in HEALTH_TOP_KEYS:
        if k not in doc:
            errors.append(f"top level: missing key '{k}'")
    if doc.get("version") != HEALTH_VERSION:
        errors.append(f"version: expected {HEALTH_VERSION}, "
                      f"got {doc.get('version')!r}")
    counts = doc.get("counts", {})
    if isinstance(counts, dict):
        for kind in ALERT_KINDS:
            if not isinstance(counts.get(kind), int) \
                    or isinstance(counts.get(kind), bool):
                errors.append(f"counts.{kind}: expected int, "
                              f"got {counts.get(kind)!r}")
        for kind in counts:
            if kind not in ALERT_KINDS:
                errors.append(f"counts: unknown alert kind {kind!r}")
    else:
        errors.append("counts: expected object")
    alerts = doc.get("alerts", [])
    if not isinstance(alerts, list):
        errors.append("alerts: expected list")
        alerts = []
    for i, a in enumerate(alerts):
        _check_fields(f"alerts[{i}]", a, HEALTH_ALERT_SCHEMA, errors)
        if isinstance(a, dict):
            if a.get("kind") not in ALERT_KINDS:
                errors.append(f"alerts[{i}].kind: unknown {a.get('kind')!r}")
            if a.get("level") not in ALERT_LEVELS:
                errors.append(f"alerts[{i}].level: expected one of "
                              f"{ALERT_LEVELS}, got {a.get('level')!r}")
    if isinstance(counts, dict) and isinstance(doc.get("alerts"), list) \
            and all(isinstance(a, dict) for a in alerts):
        for kind in ALERT_KINDS:
            n = sum(1 for a in alerts if a.get("kind") == kind)
            if counts.get(kind) not in (None, n):
                errors.append(f"counts.{kind}: {counts[kind]} does not "
                              f"match {n} alert(s) of that kind")
    if doc.get("halted") is True and doc.get("fatal") is False:
        errors.append("halted: cannot be true without a fatal alert")
    return errors


# ---------------------------------------------------------------------------
# observability artifacts (repro.obs exporters)
# ---------------------------------------------------------------------------
# Single definitions live with the writers; re-exported here so the
# validators and the exporters cannot drift apart.
from repro.obs.export import (METRICS_CSV_HEADER, TRACE_KIND,  # noqa: E402
                              TRACE_VERSION)

_NUM = (int, float)

# span-stream event as written by Tracer: "X" complete spans and "i"
# instants share one uniform shape (instants have dur 0); every event
# carries the structural fields the trace CLI and the determinism tests
# key on.
TRACE_EVENT_SCHEMA: Dict[str, Any] = {
    "ph": str,
    "name": str,
    "cat": str,
    "ts": _NUM,
    "dur": _NUM,
    "pid": int,
    "tid": int,
    "seq": int,
    "parent": (int, type(None)),
    "depth": int,
    "args": dict,
}


def _check_event(where: str, e: Any, errors: List[str]):
    _check_fields(where, e, TRACE_EVENT_SCHEMA, errors)
    if not isinstance(e, dict):
        return
    if e.get("ph") not in ("X", "i"):
        errors.append(f"{where}.ph: expected 'X' or 'i', "
                      f"got {e.get('ph')!r}")
    if e.get("ph") == "X" and isinstance(e.get("dur"), _NUM) \
            and not isinstance(e.get("dur"), bool) and e["dur"] < 0:
        errors.append(f"{where}.dur: negative ({e['dur']!r})")


def validate_trace_jsonl(header: Any, events: Any) -> List[str]:
    """Validate a ``(header, events)`` pair as returned by
    ``repro.obs.read_jsonl``; returns a list of problems."""
    errors: List[str] = []
    if not isinstance(header, dict):
        return [f"header: expected object, got {type(header).__name__}"]
    if header.get("kind") != TRACE_KIND:
        errors.append(f"header.kind: expected {TRACE_KIND!r}, "
                      f"got {header.get('kind')!r}")
    if header.get("version") != TRACE_VERSION:
        errors.append(f"header.version: expected {TRACE_VERSION}, "
                      f"got {header.get('version')!r}")
    if not isinstance(header.get("tracks"), dict):
        errors.append("header.tracks: expected object")
    if not isinstance(events, list) or not events:
        errors.append("events: expected a non-empty list")
        return errors
    for i, e in enumerate(events):
        _check_event(f"events[{i}]", e, errors)
    return errors


CHROME_TOP_KEYS = ("traceEvents", "displayTimeUnit")
CHROME_INSTANT_SCOPES = ("t", "p", "g")


def validate_chrome_trace(doc: Any) -> List[str]:
    """Validate a Chrome ``trace_event`` JSON document (the format
    Perfetto / chrome://tracing loads): ``{"traceEvents": [...]}`` with
    complete ("X", ts+dur in µs), instant ("i", explicit scope) and
    metadata ("M", thread_name) events. Returns a list of problems."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"top level: expected object, got {type(doc).__name__}"]
    for k in CHROME_TOP_KEYS:
        if k not in doc:
            errors.append(f"top level: missing key '{k}'")
    events = doc.get("traceEvents", [])
    if not isinstance(events, list) or not events:
        errors.append("traceEvents: expected a non-empty list")
        return errors
    for i, e in enumerate(events):
        w = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            errors.append(f"{w}: expected object, got {type(e).__name__}")
            continue
        ph = e.get("ph")
        for field, types in (("name", str), ("pid", int), ("tid", int),
                             ("args", dict)):
            v = e.get(field)
            if not isinstance(v, types) or isinstance(v, bool):
                errors.append(f"{w}.{field}: expected "
                              f"{types.__name__}, got {type(v).__name__}")
        if ph == "M":
            if e.get("name") != "thread_name" or \
                    not isinstance(e.get("args", {}).get("name"), str):
                errors.append(f"{w}: metadata event must be thread_name "
                              f"with args.name")
            continue
        if ph not in ("X", "i"):
            errors.append(f"{w}.ph: expected 'X'/'i'/'M', got {ph!r}")
            continue
        if not isinstance(e.get("ts"), _NUM) or isinstance(e["ts"], bool):
            errors.append(f"{w}.ts: expected number")
        if not isinstance(e.get("cat"), str):
            errors.append(f"{w}.cat: expected str")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, _NUM) or isinstance(dur, bool) \
                    or dur < 0:
                errors.append(f"{w}.dur: expected non-negative number, "
                              f"got {dur!r}")
        if ph == "i" and e.get("s") not in CHROME_INSTANT_SCOPES:
            errors.append(f"{w}.s: instant needs scope in "
                          f"{CHROME_INSTANT_SCOPES}, got {e.get('s')!r}")
    return errors


METRIC_TYPES = ("counter", "gauge", "histogram")
HISTOGRAM_FIELDS = ("count", "sum", "min", "max", "mean")


def validate_metrics_csv(text: Any) -> List[str]:
    """Validate the flattened ``metric,type,field,value`` CSV that
    ``repro.obs.export.write_metrics_csv`` emits."""
    errors: List[str] = []
    if not isinstance(text, str):
        return [f"top level: expected str, got {type(text).__name__}"]
    lines = [ln for ln in text.splitlines() if ln.strip()]
    if not lines or lines[0] != METRICS_CSV_HEADER:
        errors.append(f"line 1: expected header {METRICS_CSV_HEADER!r}, "
                      f"got {(lines[0] if lines else '')!r}")
        return errors
    if len(lines) == 1:
        errors.append("no metric rows")
    for i, ln in enumerate(lines[1:], start=2):
        parts = ln.split(",")
        if len(parts) != 4:
            errors.append(f"line {i}: expected 4 fields, got {len(parts)}")
            continue
        name, mtype, field, value = parts
        if not name:
            errors.append(f"line {i}: empty metric name")
        if mtype not in METRIC_TYPES:
            errors.append(f"line {i}: unknown metric type {mtype!r}")
        elif mtype in ("counter", "gauge") and field != "value":
            errors.append(f"line {i}: {mtype} field must be 'value', "
                          f"got {field!r}")
        elif mtype == "histogram" and field not in HISTOGRAM_FIELDS:
            errors.append(f"line {i}: unknown histogram field {field!r}")
        try:
            float(value)
        except ValueError:
            errors.append(f"line {i}: value {value!r} is not numeric")
    return errors
