"""Benchmark driver — one benchmark per paper table/figure.

  table1   FedMoCo vs FedMoCo-LW resources (paper Table 1)
  table2   per-stage exchange characteristics (paper Table 2)
  table3   cost multipliers, all methods (paper Table 3 cost columns)
  table4   auxiliary-data amount (paper Table 4, reduced-scale FL)
  fig5     per-round memory / FLOPs / download / upload curves
  fig6     batch size vs peak memory
  fig14    rounds-per-stage allocation -> effective rounds per layer
  kernels  Pallas kernels vs jnp oracle (allclose + timing)
  roofline dry-run roofline table (reads results/dryrun_*.json)
  engine   sequential vs vmap round engine throughput
  transport wire payload pack/unpack throughput + per-codec compression
           per schedule (writes results/transport_bench.json)
  simulation heterogeneous-fleet round policies: wall-clock to target
           loss, device-seconds, energy, drops per schedule x fleet x
           policy (writes results/simulation_bench.json)
  privacy  DP-FedAvg + secure aggregation: utility delta, (eps, delta),
           wire/mask overhead and rounds/sec per schedule x codec x
           privacy mode (writes results/privacy_bench.json)
  resources measured FLOPs/memory from the compiled XLA round programs
           vs the analytic roofline vs the paper's Table 3 multipliers,
           per engine x schedule (writes results/resources_bench.json)

``python -m benchmarks.run`` runs the fast set (``--only`` takes a
comma-separated subset); ``--full`` adds the reduced-scale FL accuracy
benchmarks (table4), which train for real. Every written document
carries the shared provenance header (``benchmarks.provenance``) and is
validated against ``benchmarks.schemas`` before it hits disk;
``benchmarks.compare`` diffs results against the committed baselines
under ``benchmarks/baselines/``.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np                                        # noqa: E402

from benchmarks import resources                          # noqa: E402
from benchmarks.provenance import provenance              # noqa: E402
from repro.obs import NOOP_OBS                            # noqa: E402

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"

# ``--trace`` swaps this for an enabled bundle; benches that run real FL
# rounds pass it into run_fedssl so the bench trace shows the full span
# tree (docs/observability.md).
OBS = NOOP_OBS

# schedule names / paper Table 3 cost multipliers — single definitions
# in repro.core.schedule and repro.roofline.client_costs
from repro.core.schedule import SCHEDULES                 # noqa: E402

NAMES = resources.SCHEDULE_NAMES
PAPER_MULT = resources.PAPER_MULT


def bench_table1():
    print("\n== Table 1: FedMoCo (e2e) vs FedMoCo-LW (layer-wise), "
          "per client ==")
    rows = {}
    for s in ("e2e", "layerwise"):
        rows[s] = resources.schedule_costs(s)
    print(f"{'':14s} {'Memory(MB)':>12s} {'FLOPs(x1e10)':>14s} "
          f"{'Comm(MB)':>10s}")
    for s, r in rows.items():
        print(f"{NAMES[s]:14s} {r['peak_memory'] / 1e6:12.0f} "
              f"{r['flops_total'] / 1e10:14.2f} "
              f"{r['comm_total'] / 1e6:10.0f}")
    m = rows["e2e"]["peak_memory"] / rows["layerwise"]["peak_memory"]
    f = rows["e2e"]["flops_total"] / rows["layerwise"]["flops_total"]
    c = rows["e2e"]["comm_total"] / rows["layerwise"]["comm_total"]
    print(f"reduction LW vs e2e: memory {m:.1f}x  flops {f:.1f}x  "
          f"comm {c:.1f}x   (paper Table 1: 4.0x, 2.9x, 12x)")
    return rows


def bench_table2():
    print("\n== Table 2: characteristics at stage s ==")
    from repro.configs.base import FLConfig
    from repro.core import schedule as sched
    print(f"{'method':12s} {'active':16s} {'frozen':14s} "
          f"{'download':12s} {'upload':10s} {'calib':6s}")
    for s in SCHEDULES:
        plans = sched.build_schedule(FLConfig(rounds=24, schedule=s), 12)
        p = plans[12]                       # a mid-training round

        def rng_(t):
            lo, hi = t
            return f"L{lo + 1}..L{hi}" if hi - lo > 1 else f"L{hi}"
        active = (f"L{p.active_from + 1}..L{p.sub_layers}"
                  if p.sub_layers - p.active_from > 1
                  else f"L{p.sub_layers}")
        frozen = f"L1..L{p.active_from}" if p.active_from else "-"
        print(f"{NAMES[s]:12s} {active:16s} {frozen:14s} "
              f"{rng_(p.download_stages):12s} {rng_(p.upload_stages):10s} "
              f"{'yes' if p.server_calibrate else 'no':6s}")


def bench_table3():
    print("\n== Table 3 (cost columns): multipliers vs FedMoCo ==")
    base = resources.schedule_costs("e2e")
    print(f"{'method':12s} {'Memory':>8s} {'FLOPs':>8s} {'Comm':>8s} "
          f"{'paper(M,F,C)':>20s}")
    out = {}
    for s in SCHEDULES:
        r = resources.schedule_costs(s)
        m = r["peak_memory"] / base["peak_memory"]
        f = r["flops_total"] / base["flops_total"]
        c = r["comm_total"] / base["comm_total"]
        pm, pf, pc = PAPER_MULT[s]
        print(f"{NAMES[s]:12s} {m:8.2f} {f:8.2f} {c:8.2f} "
              f"{f'{pm:.2f},{pf:.2f},{pc:.2f}':>20s}")
        out[s] = (m, f, c)
    return out


def bench_fig5():
    print("\n== Fig. 5: per-round curves (values at stages 1, 6, 12) ==")
    for s in SCHEDULES:
        r = resources.schedule_costs(s)
        ser = r["series"]
        idx = [0, len(ser["memory"]) // 2, -1]
        mem = [f"{ser['memory'][i] / 1e6:.0f}" for i in idx]
        dwn = [f"{ser['download'][i] / 1e6:.2f}" for i in idx]
        upl = [f"{ser['upload'][i] / 1e6:.2f}" for i in idx]
        print(f"{NAMES[s]:12s} memMB {mem}  downMB {dwn}  upMB {upl}")


def bench_fig6():
    print("\n== Fig. 6b: peak memory vs batch size ==")
    print(f"{'batch':>6s}" + "".join(f"{NAMES[s]:>14s}" for s in SCHEDULES))
    for b in (64, 128, 256, 512, 1024):
        row = [f"{b:6d}"]
        for s in SCHEDULES:
            r = resources.schedule_costs(s, batch=b)
            row.append(f"{r['peak_memory'] / 1e6:14.0f}")
        print("".join(row))


def bench_fig14():
    print("\n== Fig. 13/14: rounds-per-stage allocations ==")
    from repro.core.schedule import stage_rounds
    for alloc in ("uniform", "right_skewed", "left_skewed"):
        rs = stage_rounds(180, 12, alloc)
        # effective rounds layer L trains: layerwise -> its stage's rounds;
        # progressive -> sum of rounds from its stage onward
        prog = [sum(rs[i:]) for i in range(12)]
        print(f"{alloc:14s} per-stage {rs}")
        print(f"{'':14s} progressive effective {prog}")


def bench_kernels():
    print("\n== Pallas kernels vs oracle (interpret mode, CPU) ==")
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(0)
    rows = []
    q = jax.random.normal(key, (2, 256, 4, 64))
    k = jax.random.normal(key, (2, 256, 2, 64))
    v = jax.random.normal(key, (2, 256, 2, 64))
    t0 = time.perf_counter()
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    t_k = time.perf_counter() - t0
    want = ref.sdpa_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(out - want)))
    rows.append(("flash_attention", t_k, err))
    xh = jax.random.normal(key, (2, 256, 4, 64))
    dt = jax.nn.softplus(jax.random.normal(key, (2, 256, 4)))
    a = -dt * 0.1
    Bm = jax.random.normal(key, (2, 256, 64))
    Cm = jax.random.normal(key, (2, 256, 64))
    t0 = time.perf_counter()
    out = ops.ssd_scan(xh, dt, a, Bm, Cm, interpret=True)
    rows.append(("mamba2_ssd_scan", time.perf_counter() - t0,
                 float(jnp.max(jnp.abs(
                     out - ref.ssd_scan_ref(xh, dt, a, Bm, Cm))))))
    qq = jax.random.normal(key, (256, 128))
    kk = jax.random.normal(key, (256, 128))
    t0 = time.perf_counter()
    got = ops.fused_info_nce(qq, kk, 0.2, interpret=True)
    from repro.core.losses import info_nce
    rows.append(("fused_info_nce", time.perf_counter() - t0,
                 abs(float(got) - float(info_nce(qq, kk, 0.2)))))
    x = jax.random.normal(key, (1024, 256))
    s = jnp.ones((256,))
    t0 = time.perf_counter()
    got = ops.fused_rmsnorm(x, s, interpret=True)
    rows.append(("fused_rmsnorm", time.perf_counter() - t0,
                 float(jnp.max(jnp.abs(got - ref.rmsnorm_ref(x, s))))))
    for name, dt_, err in rows:
        print(f"{name:20s} first-call {dt_ * 1e3:8.1f}ms  maxerr {err:.2e}")
        assert err < 5e-3
    print("(interpret mode validates semantics; TPU timing is the "
          "dry-run/roofline's job)")


def bench_roofline():
    print("\n== Roofline table (from dry-run results) ==")
    found = sorted(RESULTS.glob("dryrun_*.json"))
    if not found:
        print("  (no results/dryrun_*.json yet — run "
              "python -m repro.launch.dryrun --out "
              "results/dryrun_16x16.json)")
        return
    for f in found:
        rows = json.loads(f.read_text())
        print(f"-- {f.name}: {len(rows)} rows")
        for r in rows:
            print(f"  {r['arch']:28s} {r['shape']:12s} {r['mode']:9s} "
                  f"comp {r['compute_s'] * 1e3:9.2f}ms "
                  f"mem {r['memory_s'] * 1e3:9.2f}ms "
                  f"coll {r['collective_s'] * 1e3:9.2f}ms "
                  f"-> {r['dominant']:10s} useful "
                  f"{r['useful_ratio'] * 100:5.1f}%")


def bench_engine(rounds=8, clients=8):
    """Sequential vs vmap engine throughput, 8 clients/round.

    Uses the regime the vectorized engine exists for — many clients with
    small local datasets (one local step each, as in FedSGD-style rounds) —
    where the sequential simulator's per-client dispatch overhead dominates
    wall-clock. Steady-state rounds/sec excludes round 1, which pays the
    one-time XLA compile in both engines.
    """
    print(f"\n== Engine: sequential vs vmap rounds/sec "
          f"({clients} clients/round) ==")
    import jax
    import jax.numpy as jnp
    from repro.configs.base import (FLConfig, ModelConfig, SSLConfig,
                                    TrainConfig)
    from repro.data import iid_partition, synthetic_images
    from repro.federated.driver import run_fedssl
    cfg = ModelConfig("t-vit", "dense", 2, 32, 2, 2, 64, 0, causal=False,
                      compute_dtype="float32", act="gelu")
    sslc = SSLConfig(proj_hidden=32, pred_hidden=32, proj_dim=16)
    tc = TrainConfig(batch_size=8, base_lr=1.5e-4)
    samples = clients * tc.batch_size
    key = jax.random.PRNGKey(0)
    imgs, _ = synthetic_images(key, samples, 10, 32)
    idx = [jnp.asarray(i) for i in iid_partition(samples, clients)]
    fl = FLConfig(num_clients=clients, rounds=rounds, local_epochs=1,
                  schedule="e2e")
    rps = {}
    for engine in ("sequential", "vmap"):
        times = [time.perf_counter()]
        _, hist = run_fedssl(cfg, sslc, fl, tc, images=imgs,
                             client_indices=idx, key=key, engine=engine,
                             log=lambda m: times.append(time.perf_counter()),
                             obs=OBS)
        total = times[-1] - times[0]
        rps[engine] = (rounds - 1) / (times[-1] - times[1])
        print(f"{engine:12s} {total:6.1f}s total (incl. compile)  "
              f"steady-state {rps[engine]:6.2f} rounds/s  "
              f"final loss {hist.loss[-1]:.4f}")
    print(f"vmap speedup over sequential: "
          f"{rps['vmap'] / rps['sequential']:.2f}x rounds/sec")
    return rps


def bench_transport(reps=5, codec_reps=3):
    """Wire transport, xla vs pallas engines: pack/unpack throughput per
    schedule (mid-training round, full-size ViT-T + MoCo heads), per-codec
    compression ratios, and codec encode/decode throughput on the largest
    (e2e) payload. Validates against ``benchmarks.schemas``, emits one
    BENCH json line and writes results/transport_bench.json for the CI
    artifact.

    Codec throughput uses ``codec_reps`` (the jit'd XLA top-k encode runs
    seconds per call on a 26M-element payload; best-of-3 keeps the bench
    under a minute without changing the min-statistics convention)."""
    print("\n== Transport: pack/unpack + codecs, xla vs pallas ==")
    import jax
    from benchmarks.schemas import validate_transport_bench
    from benchmarks.timing import bench_seconds, gbps
    from repro.configs.base import FLConfig, SSLConfig, load_arch
    from repro.core import schedule as sched
    from repro.core import ssl as ssl_mod
    from repro.federated import comm
    from repro.federated.transport import (Transport, kernel_codec_fns,
                                           kernel_pack, kernel_unpack,
                                           make_codec, pack_stage_payload,
                                           unpack_stage_payload)

    cfg = load_arch("vit-tiny")
    sslc = SSLConfig()
    enc = ssl_mod.make_vit_encoder(cfg)
    online = ssl_mod.ssl_init(jax.random.PRNGKey(0), enc, sslc)["online"]
    codecs = ("fp32", "fp16", "bf16", "int8", "topk:0.1")
    rows = []
    e2e_spec = None
    for schedule in SCHEDULES:
        plans = sched.build_schedule(FLConfig(rounds=24, schedule=schedule),
                                     cfg.num_layers)
        plan = plans[len(plans) // 2]
        t0s = Transport("fp32")
        spec = t0s.plan_specs(online, plan)["upload"]
        if schedule == "e2e":
            e2e_spec = spec
        nbytes = spec.payload_bytes
        xpack = jax.jit(lambda p: pack_stage_payload(p, spec))
        xunpack = jax.jit(lambda b, f: unpack_stage_payload(b, f, spec))
        flat_x = jax.block_until_ready(xpack(online))
        flat_h = kernel_pack(online, spec)
        pack_s = {"xla": bench_seconds(xpack, online, reps=reps),
                  "pallas": bench_seconds(
                      lambda: kernel_pack(online, spec), reps=reps)}
        unpack_s = {"xla": bench_seconds(xunpack, online, flat_x,
                                         reps=reps),
                    "pallas": bench_seconds(
                        lambda: kernel_unpack(online, flat_h, spec),
                        reps=reps)}
        mb = nbytes / 1e6
        # throughput figures cover the upload payload; per-codec wire_mb /
        # ratio below cover the full round trip (download + upload)
        row = {"schedule": schedule, "upload_payload_mb": round(mb, 3),
               "pack_gbps": {e: round(gbps(nbytes, s), 3)
                             for e, s in pack_s.items()},
               "unpack_gbps": {e: round(gbps(nbytes, s), 3)
                               for e, s in unpack_s.items()},
               "pack_speedup": round(pack_s["xla"] / pack_s["pallas"], 2),
               "unpack_speedup": round(
                   unpack_s["xla"] / unpack_s["pallas"], 2),
               "codecs": {}}
        analytic = comm.round_comm_bytes(online, plan)
        for name in codecs:
            t = Transport(name)
            sp = t.plan_specs(online, plan)
            wire = {d: t.wire_bytes(sp[d]) for d in ("download", "upload")}
            ratio = ((sp["download"].payload_bytes
                      + sp["upload"].payload_bytes)
                     / max(1, wire["download"] + wire["upload"]))
            row["codecs"][name] = {
                "round_wire_mb": round(
                    (wire["download"] + wire["upload"]) / 1e6, 4),
                "ratio": round(ratio, 2)}
            if name == "fp32":
                assert wire == analytic, (wire, analytic)
        rows.append(row)
        print(f"{NAMES[schedule]:12s} payload {mb:7.2f}MB  "
              f"pack {row['pack_gbps']['xla']:6.2f} -> "
              f"{row['pack_gbps']['pallas']:6.2f} GB/s "
              f"({row['pack_speedup']:.1f}x)  "
              f"unpack {row['unpack_gbps']['xla']:6.2f} -> "
              f"{row['unpack_gbps']['pallas']:6.2f} GB/s "
              f"({row['unpack_speedup']:.1f}x)")

    # codec encode/decode throughput, timed once on the largest payload
    codec_rows = []
    nbytes = e2e_spec.payload_bytes
    flat_x = jax.block_until_ready(
        jax.jit(lambda p: pack_stage_payload(p, e2e_spec))(online))
    flat_h = kernel_pack(online, e2e_spec)
    for name in codecs:
        codec = make_codec(name)
        xenc = jax.jit(lambda f: codec.encode(f, e2e_spec))
        xdec = jax.jit(lambda w: codec.decode(w, e2e_spec))
        kenc, kdec = kernel_codec_fns(codec, e2e_spec)
        wire_x = jax.block_until_ready(xenc(flat_x))
        wire_h = kenc(flat_h)
        enc_s = {"xla": bench_seconds(xenc, flat_x, reps=codec_reps,
                                      warmup=1),
                 "pallas": bench_seconds(kenc, flat_h, reps=codec_reps,
                                         warmup=1)}
        dec_s = {"xla": bench_seconds(xdec, wire_x, reps=codec_reps,
                                      warmup=1),
                 "pallas": bench_seconds(kdec, wire_h, reps=codec_reps,
                                         warmup=1)}
        crow = {"codec": name, "payload_mb": round(nbytes / 1e6, 3),
                "encode_gbps": {e: round(gbps(nbytes, s), 3)
                                for e, s in enc_s.items()},
                "decode_gbps": {e: round(gbps(nbytes, s), 3)
                                for e, s in dec_s.items()}}
        codec_rows.append(crow)
        print(f"codec {name:9s} enc {crow['encode_gbps']['xla']:8.2f} -> "
              f"{crow['encode_gbps']['pallas']:8.2f} GB/s   "
              f"dec {crow['decode_gbps']['xla']:8.2f} -> "
              f"{crow['decode_gbps']['pallas']:8.2f} GB/s")

    doc = {"bench": "transport",
           "config": {"arch": "vit-tiny", "reps": reps,
                      "codec_reps": codec_reps, "codecs": list(codecs),
                      "engines": ["xla", "pallas"],
                      "schedules": list(SCHEDULES)},
           "rows": rows, "codec_rows": codec_rows,
           "provenance": provenance()}
    errors = validate_transport_bench(doc)
    assert not errors, errors
    RESULTS.mkdir(exist_ok=True)
    out = RESULTS / "transport_bench.json"
    out.write_text(json.dumps(doc, indent=1))
    print("BENCH " + json.dumps({"bench": "transport", "rows": len(rows),
                                 "codec_rows": len(codec_rows)}))
    print(f"(schema-validated; fp32 wire bytes == analytic comm bytes "
          f"verified; json -> {out})")
    return doc


def bench_simulation(rounds=6, clients=6, clients_per_round=4,
                     schedules=("e2e", "lw_fedssl"), fleets=None,
                     policies=None, seed=0, write=True):
    """Fleet simulation: schedules x fleet profiles x round policies.

    For each (schedule, fleet) group the first policy's best round-mean
    loss becomes the group's target, and every policy reports the
    simulated wall-clock needed to reach it — alongside device-seconds,
    the energy proxy and dropped client-rounds. Writes
    results/simulation_bench.json (validated against
    benchmarks.schemas) and emits one BENCH json line. Tests call this
    with smaller knobs and ``write=False``.
    """
    print("\n== Simulation: fleet x round-policy cost frontier ==")
    import jax
    import jax.numpy as jnp
    from repro.configs.base import (FLConfig, ModelConfig, SSLConfig,
                                    TrainConfig)
    from repro.data import iid_partition, synthetic_images
    from repro.federated import fleet as fleet_mod
    from repro.federated import simulation as sim_mod
    from repro.federated.driver import run_fedssl
    from benchmarks.schemas import validate_simulation_bench

    fleets = tuple(fleets or fleet_mod.PROFILES)
    policies = tuple(policies or sim_mod.POLICIES)
    cfg = ModelConfig("t-vit", "dense", 2, 32, 2, 2, 64, 0, causal=False,
                      compute_dtype="float32", act="gelu")
    sslc = SSLConfig(proj_hidden=32, pred_hidden=32, proj_dim=16)
    tc = TrainConfig(batch_size=8, base_lr=1.5e-4)
    samples = clients * 2 * tc.batch_size
    imgs, _ = synthetic_images(jax.random.PRNGKey(seed), samples, 10, 32)
    idx = [jnp.asarray(i) for i in iid_partition(samples, clients)]
    rows = []
    for schedule in schedules:
        fl = FLConfig(num_clients=clients, rounds=rounds, local_epochs=1,
                      clients_per_round=clients_per_round,
                      schedule=schedule)
        for prof in fleets:
            target = None
            for policy in policies:
                sim = sim_mod.make_sim(
                    fleet_mod.make_fleet(prof, clients, seed=seed),
                    policy, num_clients=clients, seed=seed)
                _, hist = run_fedssl(cfg, sslc, fl, tc, images=imgs,
                                     client_indices=idx,
                                     key=jax.random.PRNGKey(seed), sim=sim,
                                     obs=OBS)
                if target is None:     # first policy sets the group bar
                    target = min(hist.loss)
                ttt = hist.wall_clock_to_loss(target)
                rows.append({
                    # the full versioned round series rides along so the
                    # bench json round-trips through FLHistory.from_dict
                    "history": hist.to_dict(),
                    "schedule": schedule, "fleet": prof, "policy": policy,
                    "rounds": rounds, "clients": clients,
                    "clients_per_round": clients_per_round,
                    "target_loss": round(float(target), 6),
                    "final_loss": round(float(hist.loss[-1]), 6),
                    "wall_clock_to_target_s":
                        None if ttt is None else round(float(ttt), 6),
                    "total_wall_clock_s":
                        round(float(hist.total_wall_clock), 6),
                    "device_seconds":
                        round(float(hist.total_device_seconds), 6),
                    "energy_j": round(float(hist.total_energy), 6),
                    "dropped_client_rounds": int(hist.total_dropped),
                })
                r = rows[-1]
                tt = (f"{r['wall_clock_to_target_s']:.2f}s"
                      if r["wall_clock_to_target_s"] is not None
                      else "  -  ")
                print(f"{schedule:10s} {prof:18s} {policy:14s} "
                      f"to-target {tt:>8s}  wall "
                      f"{r['total_wall_clock_s']:7.2f}s  dev "
                      f"{r['device_seconds']:7.2f}s  "
                      f"{r['energy_j']:6.2f}J  "
                      f"dropped {r['dropped_client_rounds']}")
    doc = {"bench": "simulation",
           "config": {"rounds": rounds, "clients": clients,
                      "clients_per_round": clients_per_round,
                      "seed": seed, "schedules": list(schedules),
                      "fleets": list(fleets), "policies": list(policies),
                      "engine": "sequential"},
           "rows": rows, "provenance": provenance(seed=seed)}
    errors = validate_simulation_bench(doc)
    assert not errors, errors
    if write:
        RESULTS.mkdir(exist_ok=True)
        out = RESULTS / "simulation_bench.json"
        out.write_text(json.dumps(doc, indent=1))
        print("BENCH " + json.dumps({"bench": "simulation",
                                     "rows": len(rows)}))
        print(f"(schema-validated; json -> {out})")
    return doc


def bench_privacy(rounds=4, clients=4, schedules=("e2e", "lw_fedssl"),
                  codecs=("fp32", "int8", "topk:0.25"), seed=0, write=True):
    """Privacy: codec x schedule x (DP, secure-agg) cost frontier.

    For every schedule x codec cell, four runs — baseline, client-level
    DP (clip=1, z=1.1), pairwise-mask secure aggregation, and both —
    reporting utility delta vs the cell's baseline, the (eps, delta)
    spent, measured wire MB plus the secure-agg mask overhead, and the
    steady-state rounds/sec cost. Writes results/privacy_bench.json
    (validated against benchmarks.schemas) and emits one BENCH json
    line. Tests call this with smaller knobs and ``write=False``.
    """
    print("\n== Privacy: DP / secure-agg utility + overhead frontier ==")
    import jax
    import jax.numpy as jnp
    from benchmarks.schemas import validate_privacy_bench
    from repro.configs.base import (FLConfig, ModelConfig, SSLConfig,
                                    TrainConfig)
    from repro.data import iid_partition, synthetic_images
    from repro.federated.driver import run_fedssl
    from repro.privacy import PrivacyConfig

    cfg = ModelConfig("t-vit", "dense", 2, 32, 2, 2, 64, 0, causal=False,
                      compute_dtype="float32", act="gelu")
    sslc = SSLConfig(proj_hidden=32, pred_hidden=32, proj_dim=16)
    tc = TrainConfig(batch_size=8, base_lr=1.5e-4)
    samples = clients * 2 * tc.batch_size
    imgs, _ = synthetic_images(jax.random.PRNGKey(seed), samples, 10, 32)
    idx = [jnp.asarray(i) for i in iid_partition(samples, clients)]
    modes = (("baseline", None),
             ("dp", PrivacyConfig(clip=1.0, noise_multiplier=1.1)),
             ("secure", PrivacyConfig(secure_agg=True)),
             ("dp+secure", PrivacyConfig(clip=1.0, noise_multiplier=1.1,
                                         secure_agg=True)))
    rows = []
    for schedule in schedules:
        fl = FLConfig(num_clients=clients, rounds=rounds, local_epochs=1,
                      schedule=schedule)
        for codec in codecs:
            base_loss = base_rps = None
            for mode, privacy in modes:
                times = [time.perf_counter()]
                _, hist = run_fedssl(
                    cfg, sslc, fl, tc, images=imgs, client_indices=idx,
                    key=jax.random.PRNGKey(seed), codec=codec,
                    privacy=privacy, obs=OBS,
                    log=lambda m: times.append(time.perf_counter()))
                # steady-state rounds/sec: round 1 pays the XLA compile
                rps = (rounds - 1) / max(times[-1] - times[1], 1e-9)
                if mode == "baseline":
                    base_loss, base_rps = hist.loss[-1], rps
                dp = privacy is not None and privacy.clip > 0.0
                rows.append({
                    "schedule": schedule, "codec": codec, "dp": dp,
                    "secure_agg": bool(privacy is not None
                                       and privacy.secure_agg),
                    "rounds": rounds, "clients": clients,
                    "final_loss": round(float(hist.loss[-1]), 6),
                    "utility_delta": round(
                        float(hist.loss[-1] - base_loss), 6),
                    "epsilon": (round(float(hist.epsilon[-1]), 6)
                                if dp else None),
                    "clip_fraction": (round(float(
                        np.mean(hist.clip_fraction)), 6) if dp else None),
                    "wire_mb": round(float(hist.total_wire) / 1e6, 4),
                    "mask_overhead_mb": round(float(
                        sum(hist.secure_agg_overhead_bytes)) / 1e6, 4),
                    "rounds_per_sec": round(rps, 4),
                    "slowdown": round(base_rps / max(rps, 1e-9), 3),
                })
                r = rows[-1]
                eps = (f"eps {r['epsilon']:7.2f}" if r["epsilon"]
                       is not None else "eps    -  ")
                print(f"{schedule:10s} {codec:10s} {mode:10s} "
                      f"loss {r['final_loss']:7.4f} "
                      f"(d {r['utility_delta']:+8.4f})  {eps}  "
                      f"wire {r['wire_mb']:6.2f}MB "
                      f"+mask {r['mask_overhead_mb']:5.2f}MB  "
                      f"{r['rounds_per_sec']:5.2f} r/s "
                      f"({r['slowdown']:.2f}x)")
    doc = {"bench": "privacy",
           "config": {"rounds": rounds, "clients": clients, "seed": seed,
                      "schedules": list(schedules), "codecs": list(codecs),
                      "modes": [m for m, _ in modes],
                      "dp_clip": 1.0, "dp_noise_multiplier": 1.1,
                      "dp_delta": 1e-5, "engine": "sequential"},
           "rows": rows, "provenance": provenance(seed=seed)}
    errors = validate_privacy_bench(doc)
    assert not errors, errors
    if write:
        RESULTS.mkdir(exist_ok=True)
        out = RESULTS / "privacy_bench.json"
        out.write_text(json.dumps(doc, indent=1))
        print("BENCH " + json.dumps({"bench": "privacy",
                                     "rows": len(rows)}))
        print(f"(schema-validated; json -> {out})")
    return doc


def bench_resources(engines=("sequential", "vmap"), measure_rounds=20,
                    compile_memory=True, seed=0, write=True):
    """Measured resources: XLA cost/memory analysis vs the analytic
    roofline, per engine x schedule.

    This is the old standalone analytic table folded into a bench suite:
    each row carries the analytic columns (``repro.roofline.client_costs``)
    next to the *measured* ones — FLOPs from ``Lowered.cost_analysis()``
    on the unrolled round programs, peak/argument/output memory from the
    compiled rolled program of each schedule's peak stage, and full-scale
    comm from the abstract transport walk (which reproduces the paper's
    0.08 / 0.31 / 0.54 comm column exactly). Writes
    results/resources_bench.json (validated against benchmarks.schemas,
    whose validator also enforces the measured-vs-analytic tolerances)
    and emits one BENCH json line. Tests call this with smaller knobs and
    ``write=False``; CI's regression job diffs the written document
    against benchmarks/baselines/ via benchmarks.compare.
    """
    print("\n== Resources: measured (XLA) vs analytic vs paper ==")
    from benchmarks.schemas import validate_resources_bench
    from repro.launch.trace import paper_table, print_paper_table

    table = paper_table(engines=tuple(engines),
                        measure_rounds=measure_rounds,
                        compile_memory=compile_memory,
                        log=print)
    print_paper_table(table)
    rows = table.pop("rows")
    doc = {"bench": "resources", "config": table, "rows": rows,
           "provenance": provenance(seed=seed)}
    errors = validate_resources_bench(doc)
    assert not errors, errors
    if write:
        RESULTS.mkdir(exist_ok=True)
        out = RESULTS / "resources_bench.json"
        out.write_text(json.dumps(doc, indent=1))
        print("BENCH " + json.dumps({"bench": "resources",
                                     "rows": len(rows)}))
        print(f"(schema-validated incl. measured-vs-analytic tolerances; "
              f"json -> {out})")
    return doc


def bench_table4(rounds=4):
    print("\n== Table 4: auxiliary data amount (reduced-scale, "
          "synthetic) ==")
    import jax
    import jax.numpy as jnp
    from repro.configs.base import (FLConfig, ModelConfig, SSLConfig,
                                    TrainConfig)
    from repro.core import ssl as ssl_mod
    from repro.data import iid_partition, synthetic_images
    from repro.federated import eval as fl_eval
    from repro.federated.driver import run_fedssl
    cfg = ModelConfig("t-vit", "dense", 4, 48, 4, 4, 96, 0, causal=False,
                      compute_dtype="float32", act="gelu")
    sslc = SSLConfig(proj_hidden=96, pred_hidden=96, proj_dim=24)
    tc = TrainConfig(batch_size=32, base_lr=1.5e-4)
    key = jax.random.PRNGKey(0)
    imgs, labels = synthetic_images(key, 512, 10, 32)
    idx = [jnp.asarray(i) for i in iid_partition(512, 2)]
    enc = ssl_mod.make_vit_encoder(cfg)
    for frac in (0.05, 0.25, 1.0):
        aux = imgs[: int(512 * frac)]
        fl = FLConfig(num_clients=2, rounds=rounds, local_epochs=1,
                      schedule="lw_fedssl", server_epochs=1)
        state, hist = run_fedssl(cfg, sslc, fl, tc, images=imgs,
                                 client_indices=idx, aux_images=aux, key=key)
        acc = fl_eval.linear_eval(enc, state["online"]["enc"],
                                  imgs[:256], labels[:256], imgs[256:],
                                  labels[256:], num_classes=10, epochs=3,
                                  batch_size=64)
        print(f"aux fraction {frac:5.2f}: final loss {hist.loss[-1]:.3f} "
              f"linear acc {acc * 100:.1f}%")


BENCHES = {
    "table1": bench_table1, "table2": bench_table2, "table3": bench_table3,
    "fig5": bench_fig5, "fig6": bench_fig6, "fig14": bench_fig14,
    "kernels": bench_kernels, "roofline": bench_roofline,
    "engine": bench_engine, "transport": bench_transport,
    "simulation": bench_simulation, "privacy": bench_privacy,
    "resources": bench_resources,
}
FULL_BENCHES = {"table4": bench_table4}


def _select_benches(only: str, benches: dict) -> dict:
    """``--only`` value (comma-separated bench names) -> ordered subset
    of ``benches``; raises ValueError on unknown or empty names so CI
    fails loudly instead of silently running nothing."""
    names = [n.strip() for n in only.split(",") if n.strip()]
    if not names:
        raise ValueError("--only: no bench names given")
    unknown = [n for n in names if n not in benches]
    if unknown:
        raise ValueError(
            f"--only: unknown bench(es) {', '.join(unknown)}; "
            f"available: {', '.join(sorted(benches))}")
    return {n: benches[n] for n in names}


def main():
    global OBS
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only these benches (comma-separated, e.g. "
                         "--only transport,privacy)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--trace", action="store_true",
                    help="span-trace the bench run (one span per bench, "
                         "full FL span trees inside) and write "
                         "results/bench_trace.jsonl + .chrome.json")
    args = ap.parse_args()
    if args.trace:
        from repro.obs import make_obs
        OBS = make_obs(trace=True, source="benchmarks.run")
    todo = dict(BENCHES)
    if args.full:
        todo.update(FULL_BENCHES)
    if args.only:
        try:
            todo = _select_benches(args.only, {**BENCHES, **FULL_BENCHES})
        except ValueError as e:
            ap.error(str(e))
    t0 = time.perf_counter()
    for name, fn in todo.items():
        with OBS.tracer.span(f"bench.{name}", cat="bench"):
            fn()
    print(f"\nall benchmarks done in {time.perf_counter() - t0:.1f}s")
    if args.trace:
        RESULTS.mkdir(exist_ok=True)
        written = OBS.export(
            trace_jsonl=RESULTS / "bench_trace.jsonl",
            chrome_trace=RESULTS / "bench_trace.chrome.json",
            benches=sorted(todo))
        for kind, path in sorted(written.items()):
            print(f"obs: wrote {kind} -> {path}")


if __name__ == "__main__":
    main()
