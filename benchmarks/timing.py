"""Shared timing conventions for the benchmark driver.

Every benchmark times the same way: ``time.perf_counter`` (monotonic,
highest available resolution — ``time.time`` is wall-clock and can step),
``WARMUP`` untimed calls first (absorbing jit compilation, lazy caches and
page-warming of pooled wire buffers), then best-of-``reps``. Best-of is
the right statistic for throughput numbers on a shared CI box: the
minimum is the least-noise estimate of the code's cost, while means fold
in scheduler jitter.

``bench_seconds`` blocks on the result via ``jax.block_until_ready``,
which walks pytrees and ignores non-jax leaves — so it times jax, numpy
(hostwire) and mixed outputs uniformly.
"""
from __future__ import annotations

import time

import jax

WARMUP = 2


def bench_seconds(fn, *args, reps: int = 5, warmup: int = WARMUP) -> float:
    """Best-of-``reps`` seconds for ``fn(*args)`` after ``warmup`` untimed
    calls, synchronized with ``jax.block_until_ready``."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def gbps(nbytes: int, seconds: float) -> float:
    """Throughput in GB/s, guarded against zero-duration measurements."""
    return nbytes / 1e9 / max(seconds, 1e-9)
