"""Shared provenance header for every bench JSON under results/.

Each document the bench runner writes carries a ``provenance`` object so
a results file can always be traced back to the exact tree, seed and
toolchain that produced it — without it, a committed artifact and the
code drift apart silently (see benchmarks/schemas.py's module docstring
for the incident that motivated schema validation in the first place).

``provenance(seed=...)`` is cheap (one git subprocess, cached) and never
raises: outside a git checkout the commit is recorded as "unknown".
"""
from __future__ import annotations

import datetime
import functools
import platform as platform_mod
import subprocess
from pathlib import Path
from typing import Any, Dict, Optional

PROVENANCE_VERSION = 1

_REPO_ROOT = Path(__file__).resolve().parent.parent


@functools.lru_cache(maxsize=1)
def git_commit() -> str:
    """The current HEAD commit hash, or "unknown" outside a checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=_REPO_ROOT,
            capture_output=True, text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except Exception:
        pass
    return "unknown"


def provenance(seed: Optional[int] = None) -> Dict[str, Any]:
    """Build the provenance header stamped into every bench document."""
    import jax
    import jaxlib
    return {
        "version": PROVENANCE_VERSION,
        "git_commit": git_commit(),
        "seed": seed,
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        "platform": platform_mod.platform(),
        "python": platform_mod.python_version(),
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
    }
