"""Bench regression gate: diff results/ documents against committed
baselines under benchmarks/baselines/.

CI's ``regression`` job runs the seeded benches and then::

    python -m benchmarks.compare

which pairs every ``benchmarks/baselines/<name>_bench.json`` with the
freshly written ``results/<name>_bench.json``, validates both against
``benchmarks.schemas``, and compares only the *deterministic* metrics —
seeded losses, analytic and XLA-measured costs, wire sizes — each with
an explicit per-metric tolerance. Timing metrics (GB/s, rounds/sec,
wall-clock) and the provenance header are never compared: they vary per
host and would make the gate flaky. Any drift, missing row, or new row
is reported and the process exits nonzero.

To accept an intentional change, regenerate the bench and copy the new
document over the baseline::

    python -m benchmarks.run --only resources
    cp results/resources_bench.json benchmarks/baselines/

Single-file usage (explicit pair)::

    python -m benchmarks.compare results/resources_bench.json \
        benchmarks/baselines/resources_bench.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

REPO = pathlib.Path(__file__).resolve().parents[1]
RESULTS = REPO / "results"
BASELINES = REPO / "benchmarks" / "baselines"


@dataclass(frozen=True)
class BenchSpec:
    """What to compare for one bench family: how rows are keyed, and
    which metrics gate with which (rtol, atol). Metric paths are dotted
    and may use ``*`` to fan out over a dict level (codec tables)."""
    key: Tuple[str, ...]
    metrics: Dict[str, Tuple[float, float]]


# Deterministic-metric gate per bench. Everything not listed is ignored
# on purpose — notably all throughput/wall-clock numbers and the
# ``history`` blobs the simulation rows embed.
SPECS: Dict[str, BenchSpec] = {
    "resources": BenchSpec(
        key=("engine", "schedule"),
        metrics={
            # XLA cost analysis is deterministic for a fixed jax
            # version; the slack absorbs cross-version flop-count shifts
            "flops_total": (0.05, 0.0),
            "analytic_flops_total": (1e-6, 0.0),
            "analytic_peak_memory": (1e-6, 0.0),
            "program_peak_analytic": (1e-6, 0.0),
            # buffer assignment moves more than flop counts do
            "peak_memory": (0.25, 0.0),
            "comm_bytes": (0.0, 0.0),
            "comm_ratio": (1e-9, 0.0),
            "flops_ratio": (0.05, 0.0),
            "analytic_flops_ratio": (1e-6, 0.0),
            "analytic_memory_ratio": (1e-6, 0.0),
        }),
    "simulation": BenchSpec(
        key=("schedule", "fleet", "policy"),
        metrics={
            # simulated clocks/energy are seeded model outputs, not
            # host timings — they must reproduce exactly-ish
            "final_loss": (1e-3, 1e-6),
            "target_loss": (1e-3, 1e-6),
            "total_wall_clock_s": (1e-6, 0.0),
            "device_seconds": (1e-6, 0.0),
            "energy_j": (1e-6, 0.0),
            "dropped_client_rounds": (0.0, 0.0),
            "wall_clock_to_target_s": (1e-6, 0.0),
        }),
    "transport": BenchSpec(
        key=("schedule",),
        metrics={
            "upload_payload_mb": (1e-6, 0.0),
            "codecs.*.round_wire_mb": (1e-6, 0.0),
            "codecs.*.ratio": (1e-6, 0.0),
        }),
    "privacy": BenchSpec(
        key=("schedule", "codec", "dp", "secure_agg"),
        metrics={
            "final_loss": (1e-3, 1e-6),
            "utility_delta": (0.0, 2e-3),
            "epsilon": (1e-6, 0.0),
            "wire_mb": (1e-6, 0.0),
            "mask_overhead_mb": (1e-6, 0.0),
        }),
}

VALIDATORS = {
    "resources": "validate_resources_bench",
    "simulation": "validate_simulation_bench",
    "transport": "validate_transport_bench",
    "privacy": "validate_privacy_bench",
}


def _row_key(row: dict, fields: Tuple[str, ...]) -> tuple:
    return tuple(row.get(f) for f in fields)


def _lookup(row: Any, path: str) -> List[Tuple[str, Any]]:
    """Resolve a dotted metric path; ``*`` fans out over dict keys.
    Returns ``[(concrete_path, value), ...]`` — a missing segment yields
    a single ``(path, KeyError)`` marker so drift is reported, not
    swallowed."""
    out = [("", row)]
    for seg in path.split("."):
        nxt = []
        for prefix, v in out:
            if not isinstance(v, dict):
                nxt.append((prefix or path, KeyError))
                continue
            if seg == "*":
                for k in sorted(v):
                    nxt.append((f"{prefix}.{k}" if prefix else k, v[k]))
            elif seg in v:
                nxt.append((f"{prefix}.{seg}" if prefix else seg, v[seg]))
            else:
                nxt.append((f"{prefix}.{seg}" if prefix else seg, KeyError))
        out = nxt
    return out


def _drifted(base: Any, new: Any, rtol: float, atol: float) -> bool:
    if base is None or new is None:
        return base is not new
    if isinstance(base, bool) or isinstance(new, bool) \
            or not isinstance(base, (int, float)) \
            or not isinstance(new, (int, float)):
        return base != new
    return abs(new - base) > max(atol, rtol * abs(base))


def compare_docs(bench: str, result: dict, baseline: dict) -> List[str]:
    """Compare a result document against its baseline; returns a list of
    human-readable drift problems (empty = gate passes)."""
    spec = SPECS.get(bench)
    if spec is None:
        return [f"{bench}: no comparison spec (update benchmarks/compare.py)"]
    problems: List[str] = []
    base_rows = {_row_key(r, spec.key): r for r in baseline.get("rows", [])}
    new_rows = {_row_key(r, spec.key): r for r in result.get("rows", [])}
    for key in sorted(set(base_rows) - set(new_rows), key=repr):
        problems.append(f"{bench}: row {key} in baseline but missing from "
                        f"results — coverage shrank")
    for key in sorted(set(new_rows) - set(base_rows), key=repr):
        problems.append(f"{bench}: new row {key} not in baseline — "
                        f"refresh benchmarks/baselines/")
    for key in sorted(set(base_rows) & set(new_rows), key=repr):
        brow, nrow = base_rows[key], new_rows[key]
        for path, (rtol, atol) in spec.metrics.items():
            bvals = dict(_lookup(brow, path))
            nvals = dict(_lookup(nrow, path))
            for cpath in sorted(set(bvals) | set(nvals)):
                b = bvals.get(cpath, KeyError)
                n = nvals.get(cpath, KeyError)
                if b is KeyError and n is KeyError:
                    continue
                if b is KeyError or n is KeyError:
                    problems.append(f"{bench}: row {key} metric {cpath} "
                                    f"present on only one side")
                elif _drifted(b, n, rtol, atol):
                    problems.append(
                        f"{bench}: row {key} metric {cpath} drifted: "
                        f"baseline {b!r} -> {n!r} "
                        f"(rtol {rtol:g}, atol {atol:g})")
    return problems


def _bench_name(doc: dict, path: pathlib.Path) -> str:
    name = doc.get("bench")
    if not isinstance(name, str):
        raise ValueError(f"{path}: not a bench document (no 'bench' key)")
    return name


def _validate(doc: dict, path: pathlib.Path) -> List[str]:
    import benchmarks.schemas as schemas
    fn = VALIDATORS.get(doc.get("bench"))
    if fn is None:
        return [f"{path}: no schema validator for bench "
                f"{doc.get('bench')!r}"]
    return [f"{path}: {e}" for e in getattr(schemas, fn)(doc)]


def compare_files(result_path: pathlib.Path,
                  baseline_path: pathlib.Path) -> List[str]:
    result = json.loads(result_path.read_text())
    baseline = json.loads(baseline_path.read_text())
    bench = _bench_name(result, result_path)
    if _bench_name(baseline, baseline_path) != bench:
        return [f"bench mismatch: {result_path} is {bench!r}, "
                f"{baseline_path} is {baseline.get('bench')!r}"]
    problems = _validate(result, result_path) \
        + _validate(baseline, baseline_path)
    if problems:
        return problems
    return compare_docs(bench, result, baseline)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff bench results against committed baselines; "
                    "exits nonzero on drift")
    ap.add_argument("result", nargs="?", default=None,
                    help="results json (default: pair every baseline "
                         "with its results/ counterpart)")
    ap.add_argument("baseline", nargs="?", default=None,
                    help="baseline json (required with an explicit "
                         "result)")
    ap.add_argument("--results-dir", default=str(RESULTS))
    ap.add_argument("--baselines-dir", default=str(BASELINES))
    args = ap.parse_args(argv)

    pairs: List[Tuple[pathlib.Path, pathlib.Path]] = []
    if args.result:
        if not args.baseline:
            ap.error("explicit result needs an explicit baseline")
        pairs.append((pathlib.Path(args.result),
                      pathlib.Path(args.baseline)))
    else:
        bdir = pathlib.Path(args.baselines_dir)
        rdir = pathlib.Path(args.results_dir)
        baselines = sorted(bdir.glob("*_bench.json"))
        if not baselines:
            print(f"compare: no baselines under {bdir}", file=sys.stderr)
            return 2
        pairs = [(rdir / p.name, p) for p in baselines]

    problems: List[str] = []
    for result_path, baseline_path in pairs:
        if not result_path.exists():
            problems.append(f"{result_path}: missing — run the bench "
                            f"before comparing")
            continue
        if not baseline_path.exists():
            problems.append(f"{baseline_path}: missing baseline")
            continue
        found = compare_files(result_path, baseline_path)
        problems.extend(found)
        status = "DRIFT" if found else "ok"
        print(f"compare: {result_path.name} vs baseline -> {status}")
    for p in problems:
        print(f"  {p}", file=sys.stderr)
    if problems:
        print(f"compare: {len(problems)} problem(s); to accept an "
              f"intentional change, copy the new results over "
              f"benchmarks/baselines/", file=sys.stderr)
        return 1
    print("compare: all benches within tolerance of baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
