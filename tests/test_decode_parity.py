"""Decode-vs-forward parity: stepping the decoder token-by-token must
reproduce the full-sequence forward logits (KV caches, ring buffers,
recurrent states are exact, not approximations)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig, SSMConfig,
                                XLSTMConfig)
from repro.models import lm as lm_mod

CASES = {
    "dense": ModelConfig("t", "dense", 2, 64, 4, 2, 128, 97,
                         compute_dtype="float32"),
    "window": ModelConfig("t", "dense", 2, 64, 4, 2, 128, 97, window=8,
                          compute_dtype="float32"),
    # capacity_factor=4 => no token dropping, so the forward capacity
    # dispatch and the decode dense-expert path agree exactly
    "mla": ModelConfig("t", "moe", 2, 64, 4, 4, 0, 97,
                       compute_dtype="float32",
                       moe=MoEConfig(4, 2, 1, 128, capacity_factor=4.0),
                       mla=MLAConfig(kv_lora_rank=32, q_lora_rank=16,
                                     qk_nope_head_dim=16, qk_rope_head_dim=8,
                                     v_head_dim=16)),
    "mamba": ModelConfig("t", "ssm", 2, 64, 4, 4, 0, 97,
                         compute_dtype="float32",
                         ssm=SSMConfig(state_dim=16, head_dim=32,
                                       chunk_size=8)),
    "xlstm": ModelConfig("t", "ssm", 4, 64, 4, 4, 0, 97,
                         compute_dtype="float32",
                         xlstm=XLSTMConfig(slstm_every=2)),
    "zamba": ModelConfig("t", "hybrid", 4, 64, 4, 2, 128, 97,
                         compute_dtype="float32", attn_every=2,
                         ssm=SSMConfig(state_dim=16, head_dim=32,
                                       chunk_size=8)),
}


@pytest.mark.parametrize("name", list(CASES))
def test_decode_matches_forward(name, rng):
    cfg = CASES[name]
    B, S = 2, 16
    k1, k2 = jax.random.split(rng)
    params = lm_mod.init_lm(k1, cfg)
    toks = jax.random.randint(k2, (B, S), 0, cfg.vocab_size)

    # full forward logits at every position
    x = lm_mod.embed(params, toks, cfg)
    hidden, _ = lm_mod.forward_hidden(params, x, cfg)
    from repro.models.lm import _head_matrix
    full_logits = hidden.astype(jnp.float32) @ _head_matrix(
        params, cfg).astype(jnp.float32)

    # token-by-token decode
    caches = lm_mod.init_caches(cfg, B, S, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, i: lm_mod.decode_step(p, c, t, i, cfg))
    outs = []
    for t in range(S):
        lg, caches = step(params, caches, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)

    # MoE decode intentionally uses the dense-expert path (S==1) which is
    # mathematically identical only without capacity dropping; tolerance
    # covers the fp accumulation differences elsewhere.
    tol = 2e-2 if name == "mla" else 2e-3
    err = jnp.max(jnp.abs(dec_logits - full_logits))
    assert err < tol, (name, float(err))


def test_window_decode_ring_buffer_eviction(rng):
    """Ring buffer keeps only the window; positions past it are evicted and
    the decode logits still match the windowed full forward."""
    cfg = CASES["window"]
    B, S = 1, 24                      # window 8 << S
    params = lm_mod.init_lm(rng, cfg)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    x = lm_mod.embed(params, toks, cfg)
    hidden, _ = lm_mod.forward_hidden(params, x, cfg)
    from repro.models.lm import _head_matrix
    full_logits = hidden.astype(jnp.float32) @ _head_matrix(
        params, cfg).astype(jnp.float32)
    caches = lm_mod.init_caches(cfg, B, S, dtype=jnp.float32)
    # cache allocated at window size, not S
    assert caches["k"].shape[2] == cfg.window
    step = jax.jit(lambda p, c, t, i: lm_mod.decode_step(p, c, t, i, cfg))
    outs = []
    for t in range(S):
        lg, caches = step(params, caches, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    err = jnp.max(jnp.abs(jnp.stack(outs, 1) - full_logits))
    assert err < 2e-3, float(err)


def test_moe_interleaved_parity(rng):
    """Llama-4-style 1:1 interleaved MoE: decode == forward when the
    capacity factor admits every routed token."""
    from repro.configs.base import MoEConfig as MC
    cfg = ModelConfig("t", "moe", 4, 64, 4, 2, 128, 97,
                      compute_dtype="float32",
                      moe=MC(4, 1, 1, 128, capacity_factor=8.0, moe_every=2))
    params = lm_mod.init_lm(rng, cfg)
    toks = jax.random.randint(rng, (2, 8), 0, 97)
    x = lm_mod.embed(params, toks, cfg)
    hidden, _ = lm_mod.forward_hidden(params, x, cfg)
    from repro.models.lm import _head_matrix
    full = hidden.astype(jnp.float32) @ _head_matrix(
        params, cfg).astype(jnp.float32)
    caches = lm_mod.init_caches(cfg, 2, 8, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, i: lm_mod.decode_step(p, c, t, i, cfg))
    outs = []
    for t in range(8):
        lg, caches = step(params, caches, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    assert float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full))) < 2e-3
