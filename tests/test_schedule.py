"""Stage-schedule properties (hypothesis) + weight transfer + masks."""
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import assume, given, settings, st

from repro.configs.base import FLConfig, ModelConfig
from repro.core import schedule as sched
from repro.federated.masks import stage_update_mask


@given(rounds=st.integers(24, 400), S=st.integers(1, 24),
       alloc=st.sampled_from(["uniform", "left_skewed", "right_skewed"]))
@settings(max_examples=60, deadline=None)
def test_stage_rounds_partition(rounds, S, alloc):
    rs = sched.stage_rounds(rounds, S, alloc)
    assert len(rs) == S
    assert sum(rs) == rounds
    assert all(r >= 1 for r in rs)


@given(rounds=st.integers(12, 200), S=st.integers(2, 16))
@settings(max_examples=30, deadline=None)
def test_skew_direction(rounds, S):
    assume(rounds >= S)                 # need at least one round per stage
    left = sched.stage_rounds(rounds, S, "left_skewed")
    right = sched.stage_rounds(rounds, S, "right_skewed")
    assert left[-1] >= left[0]          # more rounds late
    assert right[0] >= right[-1]        # more rounds early


@given(schedule=st.sampled_from(sched.SCHEDULES),
       rounds=st.integers(12, 120), S=st.integers(1, 12))
@settings(max_examples=60, deadline=None)
def test_schedule_invariants(schedule, rounds, S):
    fl = FLConfig(rounds=rounds, schedule=schedule, depth_dropout=0.5)
    plans = sched.build_schedule(fl, S)
    assert len(plans) == rounds
    assert [p.round_idx for p in plans] == list(range(rounds))
    stages = [p.stage for p in plans]
    assert stages == sorted(stages)                 # monotone stages
    for p in plans:
        assert 1 <= p.stage <= S
        assert p.sub_layers == (S if schedule == "e2e" else p.stage)
        lo, hi = p.upload_stages
        assert 0 <= lo < hi <= p.sub_layers
        lo, hi = p.download_stages
        assert 0 <= lo < hi <= p.sub_layers
        if schedule == "e2e":
            assert p.active_from == 0
        elif schedule == "progressive":
            assert p.active_from == 0
        else:
            assert p.active_from == p.stage - 1
        assert p.server_calibrate == (schedule == "lw_fedssl")
        assert p.align == (schedule == "lw_fedssl")
        assert (p.depth_dropout > 0) == (schedule == "fll_dd")
    if schedule != "e2e":
        # every stage appears and each stage's first round is flagged new
        assert set(stages) == set(range(1, S + 1))
        firsts = {p.stage for p in plans if p.new_stage}
        assert firsts == set(range(1, S + 1))


def test_weight_transfer_copies_previous_block(rng):
    stacked = {"w": jax.random.normal(rng, (4, 3, 3))}
    out = sched.weight_transfer(stacked, stage=3)
    assert jnp.allclose(out["w"][2], stacked["w"][1])
    assert jnp.allclose(out["w"][0], stacked["w"][0])   # others untouched
    assert jnp.allclose(out["w"][3], stacked["w"][3])
    # stage 1: no-op
    out1 = sched.weight_transfer(stacked, stage=1)
    assert jnp.allclose(out1["w"], stacked["w"])


def test_depth_dropout_gates_never_drop_active(rng):
    for _ in range(10):
        rng, k = jax.random.split(rng)
        g = sched.depth_dropout_gates(k, 8, 5, rate=1.0)
        assert jnp.all(g[5:] == 1.0)    # active & future stages kept
        assert jnp.all(g[:5] == 0.0)    # frozen all dropped at rate 1


def test_stage_update_mask_blocks(rng):
    from repro.models import lm as lm_mod
    cfg = ModelConfig("t", "dense", 4, 32, 2, 2, 64, 50,
                      compute_dtype="float32")
    params = lm_mod.init_lm(rng, cfg)
    mask = stage_update_mask(params, sub_layers=3, active_from=2)
    m = mask["blocks"]["attn"]["wq"]
    assert m.shape[0] == 4
    assert jnp.squeeze(m[2]) == 1.0     # active stage
    assert jnp.squeeze(m[1]) == 0.0     # frozen
    assert jnp.squeeze(m[3]) == 0.0     # not yet built
    # embed frozen when prefix frozen, heads always active
    assert float(mask["embed"]) == 0.0
    assert float(mask["final_ln"]["scale"]) == 1.0
    mask0 = stage_update_mask(params, sub_layers=1, active_from=0)
    assert float(mask0["embed"]) == 1.0
