"""Data pipeline (augment / synthetic) + checkpoint roundtrip."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.checkpoint.fl_state import load_fl_state, save_fl_state
from repro.data import synthetic_images, synthetic_tokens, two_views
from repro.data.augment import augment_one


def test_two_views_shapes_and_range(rng):
    imgs = jax.random.uniform(rng, (4, 32, 32, 3))
    v1, v2 = two_views(rng, imgs)
    assert v1.shape == imgs.shape and v2.shape == imgs.shape
    assert float(jnp.min(v1)) >= 0.0 and float(jnp.max(v1)) <= 1.0
    assert not jnp.allclose(v1, v2)     # two distinct views


def test_augment_deterministic_per_key(rng):
    img = jax.random.uniform(rng, (32, 32, 3))
    a = augment_one(jax.random.PRNGKey(5), img)
    b = augment_one(jax.random.PRNGKey(5), img)
    assert jnp.allclose(a, b)


def test_synthetic_images_class_structure(rng):
    imgs, labels = synthetic_images(rng, 200, num_classes=10)
    assert imgs.shape == (200, 32, 32, 3)
    assert jnp.isfinite(imgs).all()
    assert int(jnp.min(labels)) >= 0 and int(jnp.max(labels)) <= 9
    # same-class images more similar than cross-class on average
    labels = np.asarray(labels)
    flat = np.asarray(imgs).reshape(200, -1)
    c0 = flat[labels == labels[0]]
    c_other = flat[labels != labels[0]]
    if len(c0) > 2 and len(c_other) > 2:
        d_in = np.mean(np.std(c0, axis=0))
        d_out = np.mean(np.std(np.concatenate([c0[:2], c_other[:20]]), axis=0))
        assert d_in < d_out + 0.1


def test_synthetic_tokens(rng):
    toks, labels = synthetic_tokens(rng, 8, 32, 100)
    assert toks.shape == (8, 32) and labels.shape == (8, 32)
    assert int(jnp.max(toks)) < 100 and int(jnp.min(toks)) >= 0
    assert jnp.all(labels[:, :-1] == toks[:, 1:])   # next-token targets


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {"a": jax.random.normal(rng, (3, 4)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                       "c": [jnp.ones((2,)), jnp.zeros((1,))]}}
    path = tmp_path / "ckpt.npz"
    save_pytree(path, tree)
    back = load_pytree(path, jax.tree.map(jnp.zeros_like, tree))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert jnp.allclose(x, y)
        assert x.dtype == y.dtype


def test_fl_state_roundtrip(tmp_path, rng):
    state = {"online": {"w": jax.random.normal(rng, (4,))}}
    save_fl_state(tmp_path / "fl", state, 17, {"stage": 3})
    like = jax.tree.map(jnp.zeros_like, state)
    back, rnd, meta = load_fl_state(tmp_path / "fl", like)
    assert rnd == 17 and meta["stage"] == 3
    assert jnp.allclose(back["online"]["w"], state["online"]["w"])
