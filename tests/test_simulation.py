"""Fleet simulator tests: determinism, policy invariants, equivalence.

Three layers:
  - host-side unit/property tests (fleet draws, pricing, staleness
    weights, policy resolve logic) — no jax training involved;
  - driver integration (marked slow): the synchronous/uniform
    bit-identical regression, deadline survivor-FedAvg equivalence,
    cross-engine determinism and the policy x fleet matrix;
  - bench schema validation (benchmarks.schemas is the single source of
    truth for results/simulation_bench.json).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import (FLConfig, ModelConfig, SSLConfig,
                                TrainConfig)
from repro.federated import driver, fleet, server, simulation

CFG = ModelConfig("t-vit", "dense", 2, 32, 2, 2, 64, 0, causal=False,
                  compute_dtype="float32", act="gelu")
SSLC = SSLConfig(proj_hidden=32, pred_hidden=32, proj_dim=16)
TC = TrainConfig(batch_size=8)
N_CLIENTS = 4
_IMAGES = jnp.asarray(
    np.random.default_rng(0).normal(size=(64, 32, 32, 3)), jnp.float32)
_INDICES = tuple(np.arange(i * 16, (i + 1) * 16) for i in range(N_CLIENTS))


@functools.lru_cache(maxsize=None)
def run_driver(policy, profile, engine="sequential", schedule="lw_fedssl",
               rounds=4, seed=0, clients_per_round=3, policy_kw=()):
    """Memoized tiny driver run; several tests share each configuration."""
    fl = FLConfig(num_clients=N_CLIENTS, rounds=rounds, local_epochs=1,
                  clients_per_round=clients_per_round, schedule=schedule)
    sim = None
    if policy is not None:
        sim = simulation.make_sim(
            fleet.make_fleet(profile, N_CLIENTS, seed=seed), policy,
            num_clients=N_CLIENTS, seed=seed, **dict(policy_kw))
    state, hist = driver.run_fedssl(
        CFG, SSLC, fl, TC, images=_IMAGES, client_indices=list(_INDICES),
        key=jax.random.PRNGKey(0), engine=engine, sim=sim)
    return state, hist, sim


# ---------------------------------------------------------------------------
# fleet draws
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(1, 32),
       profile=st.sampled_from(fleet.PROFILES))
def test_fleet_same_seed_same_draws(seed, n, profile):
    a = fleet.make_fleet(profile, n, seed)
    b = fleet.make_fleet(profile, n, seed)
    assert a.draw_signature() == b.draw_signature()
    assert len(a) == n


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000),
       profile=st.sampled_from(("mobile-mix", "pareto-stragglers")))
def test_fleet_different_seed_different_draws(seed, profile):
    a = fleet.make_fleet(profile, 16, seed)
    b = fleet.make_fleet(profile, 16, seed + 1)
    assert a.draw_signature() != b.draw_signature()


def test_fleet_profiles():
    uni = fleet.make_fleet("uniform", 8, seed=3)
    assert uni.homogeneous
    assert uni[0] == fleet.REFERENCE_DEVICE
    mix = fleet.make_fleet("mobile-mix", 64, seed=3)
    assert not mix.homogeneous
    assert all(0.0 < d.availability <= 1.0 for d in mix.devices)
    par = fleet.make_fleet("pareto-stragglers", 64, seed=3)
    # Pareto slowdowns only ever slow clients down relative to reference
    assert all(d.flops <= fleet.REF_FLOPS for d in par.devices)
    with pytest.raises(ValueError):
        fleet.make_fleet("datacenter", 4)


# ---------------------------------------------------------------------------
# sampling / pricing / weights
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 20), cpr=st.integers(0, 20),
       oc=st.floats(1.0, 4.0))
def test_sample_clients_overcommit_clamped(n, cpr, oc):
    key = jax.random.PRNGKey(42)
    got = server.sample_clients(key, n, min(cpr, n), overcommit=oc)
    assert len(got) <= n
    assert len(set(got)) == len(got)
    base = server.sample_clients(key, n, min(cpr, n))
    assert len(got) >= len(base)


def test_sample_clients_default_overcommit_is_identity():
    # overcommit=1.0 must be byte-for-byte the historical sampling call
    key = jax.random.PRNGKey(7)
    assert server.sample_clients(key, 10, 4) == server.sample_clients(
        key, 10, 4, overcommit=1.0)


@settings(max_examples=20, deadline=None)
@given(alpha=st.floats(0.0, 2.0))
def test_staleness_weights_normalized_monotone(alpha):
    counts = [16, 16, 16, 16]
    w = simulation.staleness_weights(counts, [0, 1, 2, 5], alpha)
    assert np.isclose(w.sum(), 1.0)
    assert all(w[i] >= w[i + 1] - 1e-12 for i in range(len(w) - 1))
    # zero staleness degenerates to plain sample-count weights
    w0 = simulation.staleness_weights([8, 24], [0, 0], alpha)
    np.testing.assert_allclose(w0, [0.25, 0.75])


def test_pricing_scales_with_device_and_plan():
    from repro.core import schedule as sched
    fl = FLConfig(num_clients=2, rounds=4, schedule="lw_fedssl")
    plans = sched.build_schedule(fl, 2)
    kw = dict(batch=8, tokens=64, num_stages=2)
    f_stage0 = simulation.plan_step_flops(CFG, plans[0], **kw)
    f_stage1 = simulation.plan_step_flops(CFG, plans[-1], **kw)
    assert f_stage1 > f_stage0 > 0      # deeper sub-model costs more
    slow = fleet.DeviceProfile(
        flops=fleet.REF_FLOPS / 4, mem_bw=fleet.REF_MEM_BW / 4,
        down_bw=fleet.REF_DOWN_BW, up_bw=fleet.REF_UP_BW,
        availability=1.0, j_per_flop=fleet.REF_J_PER_FLOP,
        j_per_byte=fleet.REF_J_PER_BYTE)
    kw2 = dict(steps=2, step_flops=f_stage0, step_bytes=1e6,
               down_bytes=10**6, up_bytes=10**6)
    ref = simulation.price_client_round(fleet.REFERENCE_DEVICE, **kw2)
    slw = simulation.price_client_round(slow, **kw2)
    assert slw.compute_s > ref.compute_s
    assert slw.total_s > ref.total_s
    assert ref.download_s > 0 and ref.upload_s > 0 and ref.energy_j > 0


# ---------------------------------------------------------------------------
# policy resolve logic (host-side, no training)
# ---------------------------------------------------------------------------
def _costs(times, energy=1.0):
    return {c: simulation.ClientRoundCost(0.0, t, 0.0, energy)
            for c, t in times.items()}


def test_synchronous_policy_waits_for_slowest():
    pol = simulation.make_policy("synchronous")
    out = pol.resolve(0, [0, 1, 2], _costs({0: 1.0, 1: 5.0, 2: 2.0}),
                      {0: True, 1: True, 2: False})
    assert out.train_ids == (0, 1) and out.dropped == (2,)
    assert out.wall_clock_s == 5.0 and out.device_seconds == 6.0


def test_deadline_policy_drops_stragglers():
    pol = simulation.make_policy("deadline", deadline_s=3.0, overcommit=2.0)
    out = pol.resolve(0, [0, 1, 2, 3],
                      _costs({0: 1.0, 1: 9.0, 2: 2.0, 3: 4.0}),
                      {c: True for c in range(4)})
    assert out.train_ids == (0, 2)          # 1 and 3 miss the deadline
    assert set(out.dropped) == {1, 3}
    assert out.wall_clock_s == 3.0          # server stops at the deadline
    # cut clients burn device time up to the deadline only
    assert out.device_seconds == 1.0 + 2.0 + 3.0 + 3.0
    with pytest.raises(ValueError):
        simulation.make_policy("deadline", overcommit=0.5)
    with pytest.raises(ValueError):
        simulation.make_policy("synchronous", deadline_s=1.0)
    with pytest.raises(ValueError):
        simulation.make_policy("fifo")


def test_deadline_adaptive_quantile():
    pol = simulation.make_policy("deadline", quantile=0.5)
    times = {c: float(c + 1) for c in range(5)}
    out = pol.resolve(0, list(range(5)), _costs(times),
                      {c: True for c in range(5)})
    assert out.deadline_s == 3.0            # median of 1..5
    assert out.train_ids == (0, 1, 2)


def test_buffered_async_staleness_and_flush():
    pol = simulation.make_policy("buffered-async", buffer=2)
    costs = _costs({0: 1.0, 1: 3.5, 2: 2.0})
    avail = {c: True for c in range(3)}
    out0 = pol.resolve(0, [0, 1, 2], costs, avail)
    assert out0.train_ids == (0, 1, 2)
    tree = {"w": jnp.ones((2,))}
    _, fin0 = pol.complete(out0, costs, [16, 16, 16],
                           [tree, tree, tree])
    # the two earliest arrivals (0 at t=1, 2 at t=2) aggregate; 1 pends
    assert fin0.aggregated == (0, 2)
    assert fin0.staleness == (0, 0)
    assert np.isclose(sum(fin0.weights), 1.0)
    assert fin0.wall_clock_s == 2.0
    out1 = pol.resolve(1, [0, 1, 2], costs, avail)
    assert 1 not in out1.train_ids          # still busy from round 0
    # relaunched 0 and 2 arrive at t=3 and t=4; 1's round-0 launch at
    # t=3.5 slots between them and lands here with staleness 1
    _, fin1 = pol.complete(out1, costs, [16, 16, 16],
                           [tree] * len(out1.train_ids))
    assert 1 in fin1.aggregated
    assert fin1.staleness[fin1.aggregated.index(1)] == 1
    # equal sample counts: the stale update gets the smallest weight
    assert (fin1.weights[fin1.aggregated.index(1)] == min(fin1.weights))
    # stage transition discards pending updates and reports them dropped
    out2 = pol.resolve(2, [0, 1, 2], costs, avail)
    pol.complete(out2, costs, [16, 16, 16], [tree] * len(out2.train_ids))
    pol.begin_stage()
    out3 = pol.resolve(3, [0, 1, 2], costs, avail)
    assert out3.dropped != ()               # the flushed pending update


# ---------------------------------------------------------------------------
# driver integration
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_sync_uniform_bit_identical_to_no_simulator():
    """The equivalence regression: synchronous policy + uniform fleet must
    not perturb training at all (identical RNG chain, identical floats)."""
    st0, h0, _ = run_driver(None, None)
    st1, h1, sim = run_driver("synchronous", "uniform")
    assert h0.loss == h1.loss               # exact, not allclose
    for a, b in zip(jax.tree.leaves(st0), jax.tree.leaves(st1)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert h1.total_dropped == 0
    assert len(h1.round_wall_clock) == len(h1.loss)
    assert h0.round_wall_clock == []        # no sim => no sim accounting
    assert h1.total_wall_clock > 0 and h1.total_energy > 0
    # uniform fleet: every round's wall clock is one device's round time
    assert h1.total_device_seconds >= h1.total_wall_clock


@pytest.mark.slow
def test_deadline_survivors_equal_plain_fedavg(monkeypatch):
    """Deadline aggregation == plain FedAvg over the survivor subset:
    replaying the recorded survivor sets through the sim-free driver
    reproduces the deadline run bit for bit."""
    st0, h0, _ = run_driver("deadline", "pareto-stragglers",
                            policy_kw=(("overcommit", 1.5),))
    assert h0.total_dropped > 0             # the test must exercise drops
    survivor_sets = [list(p) for p in h0.participants]
    monkeypatch.setattr(server, "sample_clients",
                        lambda *a, **kw: survivor_sets.pop(0))
    fl = FLConfig(num_clients=N_CLIENTS, rounds=4, local_epochs=1,
                  clients_per_round=3, schedule="lw_fedssl")
    st1, h1 = driver.run_fedssl(
        CFG, SSLC, fl, TC, images=_IMAGES, client_indices=list(_INDICES),
        key=jax.random.PRNGKey(0), sim=None)
    assert h0.loss == h1.loss
    for a, b in zip(jax.tree.leaves(st0), jax.tree.leaves(st1)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
@pytest.mark.parametrize("policy", simulation.POLICIES)
def test_cross_engine_and_rerun_determinism(policy):
    """Same seed => identical fleet, participants, drops and clock across
    sequential and vmap, and across repeated runs of the same engine."""
    _, hs, sim_s = run_driver(policy, "mobile-mix", engine="sequential")
    _, hv, sim_v = run_driver(policy, "mobile-mix", engine="vmap")
    assert sim_s.fleet.draw_signature() == sim_v.fleet.draw_signature()
    for a, b in zip(sim_s.records, sim_v.records):
        assert a == b                       # full RoundOutcome equality
    assert hs.participants == hv.participants
    assert hs.dropped_clients == hv.dropped_clients
    assert hs.round_wall_clock == hv.round_wall_clock
    assert hs.device_seconds == hv.device_seconds
    np.testing.assert_allclose(hs.loss, hv.loss, rtol=0, atol=1e-5)
    # repeated identical run (lru_cache bypass): fresh sim, same decisions
    fl = FLConfig(num_clients=N_CLIENTS, rounds=4, local_epochs=1,
                  clients_per_round=3, schedule="lw_fedssl")
    sim2 = simulation.make_sim(
        fleet.make_fleet("mobile-mix", N_CLIENTS, seed=0), policy,
        num_clients=N_CLIENTS, seed=0)
    _, h2 = driver.run_fedssl(
        CFG, SSLC, fl, TC, images=_IMAGES, client_indices=list(_INDICES),
        key=jax.random.PRNGKey(0), engine="sequential", sim=sim2)
    assert h2.loss == hs.loss
    assert sim2.records == sim_s.records


@pytest.mark.slow
@pytest.mark.parametrize("policy", simulation.POLICIES)
@pytest.mark.parametrize("profile", ("mobile-mix", "pareto-stragglers"))
def test_policy_matrix(policy, profile):
    """Every policy x fleet combination trains to finite losses and fills
    the simulator accounting consistently."""
    _, hist, sim = run_driver(policy, profile)
    rounds = len(hist.loss)
    assert all(np.isfinite(hist.loss))
    assert (len(hist.round_wall_clock) == len(hist.device_seconds)
            == len(hist.energy_joules) == len(hist.dropped_clients)
            == len(hist.participants) == rounds)
    assert hist.total_wall_clock > 0
    assert hist.total_device_seconds >= hist.total_wall_clock * 0.999
    assert hist.total_energy > 0
    for rec in sim.records:
        assert set(rec.train_ids) <= set(rec.cohort)
        assert not (set(rec.dropped) & set(rec.aggregated))
        if rec.weights is not None and rec.weights:
            assert np.isclose(sum(rec.weights), 1.0)
        assert len(rec.cohort) <= N_CLIENTS  # overcommit is clamped


@pytest.mark.slow
def test_wall_clock_to_loss():
    _, hist, _ = run_driver("synchronous", "uniform")
    best = min(hist.loss)
    t = hist.wall_clock_to_loss(best)
    assert t is not None
    assert 0 < t <= hist.total_wall_clock + 1e-9
    assert hist.wall_clock_to_loss(-1e9) is None


# ---------------------------------------------------------------------------
# bench schema
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_simulation_bench_schema():
    from benchmarks.run import bench_simulation
    from benchmarks.schemas import validate_simulation_bench
    doc = bench_simulation(rounds=2, clients=3, clients_per_round=2,
                           schedules=("e2e",), fleets=("uniform",),
                           seed=0, write=False)
    assert validate_simulation_bench(doc) == []
    assert len(doc["rows"]) == len(simulation.POLICIES)
    # the validator actually catches drift
    bad = {**doc, "rows": [dict(doc["rows"][0], energy_j="lots",
                                extra_field=1)]}
    errs = validate_simulation_bench(bad)
    assert any("energy_j" in e for e in errs)
    assert any("extra_field" in e for e in errs)
    assert validate_simulation_bench({}) != []


def test_checked_in_bench_artifact_if_present():
    import json
    import pathlib
    from benchmarks.schemas import validate_simulation_bench
    out = (pathlib.Path(__file__).resolve().parents[1] / "results"
           / "simulation_bench.json")
    if not out.exists():
        pytest.skip("results/simulation_bench.json not generated yet")
    assert validate_simulation_bench(json.loads(out.read_text())) == []
