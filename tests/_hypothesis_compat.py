"""Use hypothesis when installed; otherwise a plain-pytest fallback.

Property tests import ``given``/``settings``/``st`` from here. Without
hypothesis, each ``@given`` expands to a ``pytest.mark.parametrize`` over a
small fixed grid (endpoints + midpoint per strategy) so the tier-1 suite
still collects and exercises every property, just without fuzzing.
"""
import itertools

import pytest

try:
    from hypothesis import assume, given, settings, strategies as st  # noqa: F401,E501
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def assume(condition):
        if not condition:
            pytest.skip("assumption not satisfied for this fixed example")
        return True

    class _Samples:
        def __init__(self, values):
            self.values = list(values)

    class st:  # noqa: N801 - mirrors hypothesis.strategies
        @staticmethod
        def integers(lo, hi):
            return _Samples(sorted({lo, (lo + hi) // 2, hi}))

        @staticmethod
        def floats(lo, hi, **_kw):
            return _Samples(sorted({lo, (lo + hi) / 2, hi}))

        @staticmethod
        def sampled_from(values):
            return _Samples(list(values))

        @staticmethod
        def booleans():
            return _Samples([False, True])

    def settings(**_kw):
        return lambda f: f

    def given(**strategies):
        names = sorted(strategies)
        grid = list(itertools.product(*(strategies[n].values
                                        for n in names)))
        if len(names) == 1:
            # parametrize over one name takes scalars, not 1-tuples
            grid = [g[0] for g in grid]
        return lambda f: pytest.mark.parametrize(",".join(names), grid)(f)
