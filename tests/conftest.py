import os
import sys
import tempfile

# tests run on the single real CPU device (the dry-run, and only the
# dry-run, forces 512 host devices in its own process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# the FL integration tests build many small engines that jit the same
# round programs; the persistent cache deserializes repeat compilations
# (including across pytest runs) instead of re-lowering them
os.environ.setdefault(
    "JAX_COMPILATION_CACHE_DIR",
    os.path.join(tempfile.gettempdir(), "repro-jax-cache"))
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, for tests that exercise the benchmarks package
sys.path.insert(1, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running FL integration test "
        "(deselect with -m 'not slow')")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


class FakeMesh:
    """Duck-typed mesh for sharding-rule unit tests (no devices needed)."""

    def __init__(self, shape_by_axis):
        self.axis_names = tuple(shape_by_axis)
        self.shape = dict(shape_by_axis)


@pytest.fixture
def mesh16x16():
    return FakeMesh({"data": 16, "model": 16})


@pytest.fixture
def mesh2x16x16():
    return FakeMesh({"pod": 2, "data": 16, "model": 16})
