"""Core SSL machinery: losses, heads, MoCo v3 engine, momentum EMA."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SSLConfig
from repro.core import heads, losses, ssl as ssl_mod

VIT = ModelConfig("t-vit", "dense", 2, 64, 4, 4, 128, 0, causal=False,
                  compute_dtype="float32", act="gelu")
SSLC = SSLConfig(proj_hidden=64, pred_hidden=64, proj_dim=32)


def test_info_nce_identity_minimum(rng):
    """Loss is lowest when q == k (positives perfectly aligned)."""
    q = jax.random.normal(rng, (32, 16))
    perfect = losses.info_nce(q, q, 0.2)
    shuffled = losses.info_nce(q, jnp.roll(q, 1, axis=0), 0.2)
    assert perfect < shuffled


def test_info_nce_matches_manual(rng):
    q = jax.random.normal(rng, (8, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (8, 4))
    qn = np.asarray(losses.l2_normalize(q))
    kn = np.asarray(losses.l2_normalize(k))
    logits = qn @ kn.T / 0.2
    want = np.mean([-logits[i, i] + np.log(np.sum(np.exp(logits[i])))
                    for i in range(8)])
    got = float(losses.info_nce(q, k, 0.2))
    assert abs(got - want) < 1e-5


def test_simclr_symmetric(rng):
    z1 = jax.random.normal(rng, (16, 8))
    z2 = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    assert abs(float(losses.simclr_nt_xent(z1, z2, 0.5))
               - float(losses.simclr_nt_xent(z2, z1, 0.5))) < 1e-5


def test_byol_regression_range(rng):
    q = jax.random.normal(rng, (16, 8))
    assert float(losses.byol_regression(q, q)) < 1e-6
    v = float(losses.byol_regression(q, -q))
    assert abs(v - 4.0) < 1e-5      # max distance for unit vectors


def test_heads_shapes(rng):
    p = heads.proj_init(rng, 64, 128, 32)
    x = jax.random.normal(rng, (8, 64))
    out = heads.head_apply(p, x)
    assert out.shape == (8, 32)
    q = heads.pred_init(rng, 32, 128, 32)
    assert heads.head_apply(q, out).shape == (8, 32)


@pytest.mark.parametrize("method", ["moco_v3", "simclr", "byol"])
def test_ssl_loss_finite_and_grads(method, rng):
    sc = dataclasses.replace(SSLC, method=method)
    enc = ssl_mod.make_vit_encoder(VIT)
    state = ssl_mod.ssl_init(rng, enc, sc)
    x1 = jax.random.normal(rng, (8, 32, 32, 3))
    x2 = x1 + 0.01

    def loss_fn(online):
        st = {**state, "online": online}
        return ssl_mod.ssl_loss(st, x1, x2, enc, sc)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(state["online"])
    assert jnp.isfinite(loss)
    assert all(jnp.isfinite(g).all() for g in jax.tree.leaves(grads))


def test_momentum_update_ema(rng):
    enc = ssl_mod.make_vit_encoder(VIT)
    state = ssl_mod.ssl_init(rng, enc, SSLC)
    # perturb online; EMA must move target a (1-mu) fraction toward online
    online = jax.tree.map(lambda a: a + 1.0, state["online"])
    state = {**state, "online": online}
    new = ssl_mod.momentum_update(state, 0.9)
    t0 = jax.tree.leaves(state["target"])[0]
    t1 = jax.tree.leaves(new["target"])[0]
    o = jax.tree.leaves({"enc": online["enc"], "proj": online["proj"]})[0]
    assert jnp.allclose(t1, 0.9 * t0 + 0.1 * o, atol=1e-5)


def test_alignment_pulls_toward_global(rng):
    """With huge alignment weight the gradient is dominated by Eq. 3."""
    enc = ssl_mod.make_vit_encoder(VIT)
    state = ssl_mod.ssl_init(rng, enc, SSLC)
    x1 = jax.random.normal(rng, (8, 32, 32, 3))
    x2 = jax.random.normal(jax.random.PRNGKey(2), (8, 32, 32, 3))
    g_enc = jax.tree.map(lambda a: a * 1.1, state["online"]["enc"])
    l0, m0 = ssl_mod.ssl_loss(state, x1, x2, enc, SSLC,
                              global_enc=g_enc, align_weight=0.0)
    l1, m1 = ssl_mod.ssl_loss(state, x1, x2, enc, SSLC,
                              global_enc=g_enc, align_weight=0.01)
    assert "align" in m1 and "align" not in m0
    assert abs(float(l1 - l0 - 0.01 * m1["align"])) < 1e-4


def test_lm_ssl_loss_with_alignment(rng):
    cfg = ModelConfig("t", "dense", 2, 64, 4, 2, 128, 97,
                      compute_dtype="float32")
    from repro.models import lm as lm_mod
    params = lm_mod.init_lm(rng, cfg)
    tok = jax.random.randint(rng, (2, 32), 0, 97)
    loss, m = ssl_mod.lm_ssl_loss(params, {"tokens": tok, "labels": tok},
                                  cfg, sub_layers=2, active_from=1,
                                  global_params=params, align_weight=0.01)
    assert jnp.isfinite(loss) and "align" in m
