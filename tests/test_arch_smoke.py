"""Per-assigned-architecture smoke tests (deliverable f).

Each instantiates a REDUCED same-family variant (2 layers / stage groups,
d_model<=256, <=4 experts) and runs one forward/train step plus one decode
step on CPU, asserting output shapes and finiteness. Full configs are
exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, load_arch, reduced
from repro.launch.steps import is_encdec
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod

ASSIGNED = [a for a in ARCH_IDS if a != "vit-tiny"]


def _reduced(arch_id):
    cfg = load_arch(arch_id)
    over = {}
    if cfg.attn_every:              # zamba: 2 groups of 2
        over = dict(num_layers=4, attn_every=2)
    if cfg.xlstm is not None:       # xlstm: 2 groups of (1 mLSTM + 1 sLSTM)
        import dataclasses
        over = dict(num_layers=4,
                    xlstm=dataclasses.replace(cfg.xlstm, slstm_every=2))
    return reduced(cfg, **over)


@pytest.mark.parametrize("arch_id", ASSIGNED)
def test_train_step(arch_id, rng):
    cfg = _reduced(arch_id)
    B, S = 2, 64
    k1, k2 = jax.random.split(rng)
    tok = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    if is_encdec(cfg):
        params = encdec_mod.init_encdec(k2, cfg)
        batch = {"frontend": jax.random.normal(rng, (B, 16, cfg.d_model)),
                 "tokens": tok, "labels": tok}
        loss_fn = lambda p: encdec_mod.encdec_loss(p, batch, cfg)[0]  # noqa
    else:
        params = lm_mod.init_lm(k2, cfg)
        batch = {"tokens": tok, "labels": tok}
        if cfg.frontend_embed_len:
            batch["frontend"] = jax.random.normal(
                rng, (B, cfg.frontend_embed_len, cfg.d_model))
        loss_fn = lambda p: lm_mod.lm_loss(p, batch, cfg)[0]          # noqa
    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert jnp.isfinite(loss), (arch_id, loss)
    gnorm = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch_id", ASSIGNED)
def test_layerwise_stage_step(arch_id, rng):
    """Stage-2 LW step: frozen prefix gets exactly-zero grads."""
    cfg = _reduced(arch_id)
    if is_encdec(cfg):
        pytest.skip("enc-dec staging covered in test_encdec_stages")
    B, S = 2, 32
    params = lm_mod.init_lm(rng, cfg)
    tok = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    if cfg.frontend_embed_len:
        batch["frontend"] = jax.random.normal(
            rng, (B, cfg.frontend_embed_len, cfg.d_model))
    n_stage = lm_mod.num_stages(cfg)
    sub, act = n_stage, n_stage - 1

    def loss_fn(p):
        return lm_mod.lm_loss(p, batch, cfg, sub_layers=sub,
                              active_from=act)[0]

    grads = jax.jit(jax.grad(loss_fn))(params)
    for key in ("blocks", "mlstm"):
        if key not in grads:
            continue
        g = jax.tree.leaves(grads[key])
        for leaf in g:
            frozen = leaf[:act]
            assert jnp.all(frozen == 0), (arch_id, key, "frozen grads != 0")
            assert jnp.isfinite(leaf).all()


@pytest.mark.parametrize("arch_id", ASSIGNED)
def test_decode_step(arch_id, rng):
    cfg = _reduced(arch_id)
    B = 2
    if is_encdec(cfg):
        params = encdec_mod.init_encdec(rng, cfg)
        frames = jax.random.normal(rng, (B, 16, cfg.d_model))
        memory = encdec_mod.encode(params, frames, cfg)
        caches = encdec_mod.init_dec_caches(cfg, B, 32)
        tok = jnp.zeros((B, 1), jnp.int32)
        logits, caches2 = jax.jit(
            lambda p, c, t, m: encdec_mod.decode_step(
                p, c, t, jnp.int32(0), m, cfg))(params, caches, tok, memory)
    else:
        params = lm_mod.init_lm(rng, cfg)
        caches = lm_mod.init_caches(cfg, B, 32)
        tok = jnp.zeros((B, 1), jnp.int32)
        logits, caches2 = jax.jit(
            lambda p, c, t: lm_mod.decode_step(p, c, t, jnp.int32(0), cfg))(
            params, caches, tok)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), arch_id
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


def test_all_assigned_configs_load():
    for a in ASSIGNED:
        cfg = load_arch(a)
        assert cfg.arch_id == a
        assert cfg.source, f"{a} missing source citation"
        n = cfg.param_count()
        assert n > 0


def test_param_counts_order_of_magnitude():
    """Analytical parameter counts are in the advertised ballpark."""
    expect = {
        "internlm2-1.8b": (1.5e9, 2.5e9),
        "internlm2-20b": (15e9, 25e9),
        "starcoder2-15b": (12e9, 20e9),
        "mistral-large-123b": (100e9, 140e9),
        "deepseek-v2-236b": (180e9, 280e9),
        "llama4-maverick-400b-a17b": (300e9, 480e9),
        "zamba2-2.7b": (2.0e9, 3.5e9),
        "xlstm-125m": (0.08e9, 0.2e9),
        "internvl2-1b": (0.4e9, 1.2e9),
        "seamless-m4t-medium": (0.5e9, 1.6e9),
    }
    for a, (lo, hi) in expect.items():
        n = load_arch(a).param_count()
        assert lo <= n <= hi, (a, f"{n / 1e9:.2f}B not in [{lo / 1e9}B, "
                               f"{hi / 1e9}B]")


def test_input_shapes_assignment():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288


def test_xent_gold_modes_agree(rng):
    """§Perf 'mask' gold extraction is numerically identical to 'take'."""
    from repro.configs.base import ModelConfig
    from repro.models import lm as lm_mod
    cfg = ModelConfig("t", "dense", 2, 64, 4, 2, 128, 97,
                      compute_dtype="float32")
    params = lm_mod.init_lm(rng, cfg)
    tok = jax.random.randint(rng, (2, 32), 0, 97)
    batch = {"tokens": tok, "labels": tok}
    old = lm_mod.XENT_GOLD_MODE
    try:
        lm_mod.XENT_GOLD_MODE = "take"
        l1, _ = lm_mod.lm_loss(params, batch, cfg)
        lm_mod.XENT_GOLD_MODE = "mask"
        l2, _ = lm_mod.lm_loss(params, batch, cfg)
        lm_mod.XENT_GOLD_MODE = "wgather"
        l3, _ = lm_mod.lm_loss(params, batch, cfg)
    finally:
        lm_mod.XENT_GOLD_MODE = old
    assert abs(float(l1) - float(l2)) < 1e-6
    assert abs(float(l1) - float(l3)) < 1e-6
