"""Sharding rules: divisibility-safe PartitionSpecs for every arch."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCH_IDS, load_arch, reduced
from repro.launch.steps import is_encdec
from repro.sharding import rules


def _abstract_params(cfg):
    from repro.models import encdec as encdec_mod
    from repro.models import lm as lm_mod
    if is_encdec(cfg):
        return jax.eval_shape(
            lambda: encdec_mod.init_encdec(jax.random.PRNGKey(0), cfg))
    return jax.eval_shape(lambda: lm_mod.init_lm(jax.random.PRNGKey(0), cfg))


def _check_divisible(shapes, specs, mesh):
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(shapes)[0],
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % n == 0, (path, leaf.shape, spec)


ASSIGNED = [a for a in ARCH_IDS if a != "vit-tiny"]


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_divisible(arch, mesh16x16, mesh2x16x16):
    cfg = load_arch(arch)
    shapes = _abstract_params(cfg)
    for mesh in (mesh16x16, mesh2x16x16):
        specs = rules.param_pspecs(shapes, mesh)
        _check_divisible(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "deepseek-v2-236b",
                                  "zamba2-2.7b", "xlstm-125m"])
def test_big_weights_actually_sharded(arch, mesh16x16):
    """The large 2D weights must not silently fall back to replication."""
    cfg = load_arch(arch)
    shapes = _abstract_params(cfg)
    specs = rules.param_pspecs(shapes, mesh16x16)
    flat = {tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                  for p in path): (leaf, spec)
            for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(shapes)[0],
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))[0])}
    n_sharded = sum(
        1 for leaf, spec in flat.values()
        if any(e is not None for e in spec) and np.prod(leaf.shape) > 1e6)
    n_big = sum(1 for leaf, _ in flat.values() if np.prod(leaf.shape) > 1e6)
    assert n_sharded == n_big, f"{arch}: {n_big - n_sharded} big replicated"


@pytest.mark.parametrize("arch", ["internlm2-20b", "deepseek-v2-236b",
                                  "zamba2-2.7b"])
@pytest.mark.parametrize("batch", [128, 1])
def test_cache_specs_divisible(arch, batch, mesh16x16):
    from repro.models import lm as lm_mod
    cfg = load_arch(arch)
    shapes = jax.eval_shape(lambda: lm_mod.init_caches(cfg, batch, 32768))
    specs = rules.cache_pspecs(shapes, mesh16x16, batch)
    _check_divisible(shapes, specs, mesh16x16)


def test_batch1_cache_context_parallel(mesh16x16):
    """global_batch=1 long decode: seq dim shards over ALL axes."""
    from repro.models import lm as lm_mod
    cfg = load_arch("internlm2-20b")
    shapes = jax.eval_shape(lambda: lm_mod.init_caches(cfg, 1, 524288))
    specs = rules.cache_pspecs(shapes, mesh16x16, 1)
    kspec = specs["k"]
    # (L, B, W, H, hd): W entry uses both axes
    w_entry = kspec[2]
    assert w_entry == ("data", "model"), kspec


def test_batch_specs(mesh16x16):
    import jax.numpy as jnp
    b = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
         "odd": jax.ShapeDtypeStruct((7, 3), jnp.float32)}
    specs = rules.batch_specs(b, mesh16x16)
    assert specs["tokens"][0] == "data"
    assert specs["odd"][0] is None      # 7 not divisible -> replicate


def test_moe_expert_parallel(mesh16x16):
    # llama4 interleaves MoE blocks: expert stacks live under "moe_blocks"
    cfg = load_arch("llama4-maverick-400b-a17b")
    shapes = _abstract_params(cfg)
    specs = rules.param_pspecs(shapes, mesh16x16)
    wg = specs["moe_blocks"]["moe"]["w_gate"]
    # (G, E, d, ff): experts over model, d over data
    assert wg[1] == "model" and wg[2] == "data"
    # deepseek is all-MoE (uniform): experts under "blocks"
    cfg2 = load_arch("deepseek-v2-236b")
    specs2 = rules.param_pspecs(_abstract_params(cfg2), mesh16x16)
    wg2 = specs2["blocks"]["moe"]["w_gate"]
    assert wg2[1] == "model" and wg2[2] == "data"


def test_slstm_cache_spec_batch_axis(mesh2x16x16):
    """Regression: sLSTM state leaves are (..., B, d); 'n'/'m' must not be
    mistaken for the mLSTM leaves of the same name."""
    from repro.models import lm as lm_mod
    cfg = load_arch("xlstm-125m")
    shapes = jax.eval_shape(lambda: lm_mod.init_caches(cfg, 128, 32768))
    specs = rules.cache_pspecs(shapes, mesh2x16x16, 128)
    _check_divisible(shapes, specs, mesh2x16x16)
    c = specs["slstm"]["c"]          # (G, B, d)
    assert c[1] == ("pod", "data") and c[0] is None


def test_no_duplicate_axis_in_cache_spec(mesh16x16, mesh2x16x16):
    """Regression: seq and head dims must not both claim 'model'."""
    from repro.models import encdec as encdec_mod
    cfg = load_arch("seamless-m4t-medium")
    for mesh, batch in ((mesh16x16, 128), (mesh2x16x16, 128),
                        (mesh16x16, 1)):
        shapes = jax.eval_shape(
            lambda: encdec_mod.init_dec_caches(cfg, batch, 32768))
        specs = rules.cache_pspecs(shapes, mesh, batch)
        for _, spec in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]:
            flat = []
            for e in spec:
                if e is None:
                    continue
                flat += list(e) if isinstance(e, tuple) else [e]
            assert len(flat) == len(set(flat)), spec
