"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.losses import info_nce
from repro.kernels import ops, ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("B,S,T,Hq,Hkv,hd,causal,window,dtype", [
    (2, 128, 128, 4, 2, 64, True, 0, jnp.float32),
    (1, 256, 256, 4, 4, 128, True, 0, jnp.float32),
    (2, 128, 128, 8, 1, 64, False, 0, jnp.float32),
    (1, 200, 200, 4, 2, 48, True, 0, jnp.float32),   # unaligned (padding)
    (1, 384, 384, 2, 2, 96, True, 64, jnp.float32),  # sliding window
    (1, 256, 256, 4, 2, 64, True, 0, jnp.bfloat16),
    (1, 128, 128, 4, 4, 64, False, 0, jnp.bfloat16),
])
def test_flash_attention(B, S, T, Hq, Hkv, hd, causal, window, dtype, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (B, S, Hq, hd), dtype)
    k = jax.random.normal(k2, (B, T, Hkv, hd), dtype)
    v = jax.random.normal(k3, (B, T, Hkv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
    want = ref.sdpa_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=causal,
                        window=window).transpose(0, 2, 1, 3)
    assert out.shape == want.shape and out.dtype == q.dtype
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - want.astype(jnp.float32)))
    assert err < _tol(dtype), float(err)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 256, 4, 64, 64, 128),
    (1, 128, 2, 32, 16, 64),
    (1, 512, 8, 64, 64, 128),
])
def test_ssd_scan(B, S, H, P, N, chunk, rng):
    k = jax.random.split(rng, 5)
    xh = jax.random.normal(k[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(k[1], (B, S, H)))
    a = -dt * jnp.exp(jax.random.normal(k[2], (H,))) * 0.1
    Bm = jax.random.normal(k[3], (B, S, N))
    Cm = jax.random.normal(k[4], (B, S, N))
    out = ops.ssd_scan(xh, dt, a, Bm, Cm, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(xh, dt, a, Bm, Cm, chunk=chunk)
    assert jnp.max(jnp.abs(out - want)) < 5e-3


def test_ssd_scan_matches_model_layer(rng):
    """Kernel agrees with the Mamba2 layer's internal chunked scan."""
    from repro.models.layers.mamba2 import ssd_chunked
    B, S, H, P, N = 2, 256, 4, 32, 16
    k = jax.random.split(rng, 5)
    xh = jax.random.normal(k[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(k[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(k[2], (H,))) * 0.1
    Bm = jax.random.normal(k[3], (B, S, N))
    Cm = jax.random.normal(k[4], (B, S, N))
    want, _ = ssd_chunked(xh, dt, A, Bm, Cm, 128)
    got = ops.ssd_scan(xh, dt, dt * A, Bm, Cm, chunk=128, interpret=True)
    assert jnp.max(jnp.abs(got - want)) < 5e-3


@pytest.mark.parametrize("B,d", [(128, 64), (256, 128), (384, 96)])
@pytest.mark.parametrize("tau", [0.2, 1.0])
def test_fused_info_nce(B, d, tau, rng):
    k1, k2 = jax.random.split(rng)
    q = jax.random.normal(k1, (B, d))
    k = jax.random.normal(k2, (B, d))
    got = ops.fused_info_nce(q, k, tau, interpret=True)
    want = info_nce(q, k, tau)
    assert abs(float(got) - float(want)) < 1e-4


@pytest.mark.parametrize("shape", [(256, 128), (4, 96, 256), (2, 3, 64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_rmsnorm(shape, dtype, rng):
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, shape, dtype)
    s = 1.0 + 0.1 * jax.random.normal(k2, (shape[-1],))
    got = ops.fused_rmsnorm(x, s, interpret=True)
    want = ref.rmsnorm_ref(x.reshape(-1, shape[-1]), s).reshape(shape)
    err = jnp.max(jnp.abs(got.astype(jnp.float32)
                          - want.astype(jnp.float32)))
    assert err < _tol(dtype)
