"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle.

The ``wire_*`` tests parametrize over the dispatch modes available on CPU
— ``None`` (platform default: the hostwire numpy engine, or Pallas
interpret when ``REPRO_WIRE_INTERPRET`` is set, as in the CI kernels job)
and ``True`` (Pallas interpret, always). Host mode is held to bit-exact
parity with the eager XLA oracles; interpret mode gets a one-quantum
int8 allowance because the Pallas interpreter lowers fp32 division as
reciprocal-multiply (1 ulp off IEEE), which can flip a rounded value.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.losses import info_nce
from repro.kernels import ops, ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("B,S,T,Hq,Hkv,hd,causal,window,dtype", [
    (2, 128, 128, 4, 2, 64, True, 0, jnp.float32),
    (1, 256, 256, 4, 4, 128, True, 0, jnp.float32),
    (2, 128, 128, 8, 1, 64, False, 0, jnp.float32),
    (1, 200, 200, 4, 2, 48, True, 0, jnp.float32),   # unaligned (padding)
    (1, 384, 384, 2, 2, 96, True, 64, jnp.float32),  # sliding window
    (1, 256, 256, 4, 2, 64, True, 0, jnp.bfloat16),
    (1, 128, 128, 4, 4, 64, False, 0, jnp.bfloat16),
])
def test_flash_attention(B, S, T, Hq, Hkv, hd, causal, window, dtype, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (B, S, Hq, hd), dtype)
    k = jax.random.normal(k2, (B, T, Hkv, hd), dtype)
    v = jax.random.normal(k3, (B, T, Hkv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
    want = ref.sdpa_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=causal,
                        window=window).transpose(0, 2, 1, 3)
    assert out.shape == want.shape and out.dtype == q.dtype
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - want.astype(jnp.float32)))
    assert err < _tol(dtype), float(err)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 256, 4, 64, 64, 128),
    (1, 128, 2, 32, 16, 64),
    (1, 512, 8, 64, 64, 128),
])
def test_ssd_scan(B, S, H, P, N, chunk, rng):
    k = jax.random.split(rng, 5)
    xh = jax.random.normal(k[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(k[1], (B, S, H)))
    a = -dt * jnp.exp(jax.random.normal(k[2], (H,))) * 0.1
    Bm = jax.random.normal(k[3], (B, S, N))
    Cm = jax.random.normal(k[4], (B, S, N))
    out = ops.ssd_scan(xh, dt, a, Bm, Cm, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(xh, dt, a, Bm, Cm, chunk=chunk)
    assert jnp.max(jnp.abs(out - want)) < 5e-3


def test_ssd_scan_matches_model_layer(rng):
    """Kernel agrees with the Mamba2 layer's internal chunked scan."""
    from repro.models.layers.mamba2 import ssd_chunked
    B, S, H, P, N = 2, 256, 4, 32, 16
    k = jax.random.split(rng, 5)
    xh = jax.random.normal(k[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(k[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(k[2], (H,))) * 0.1
    Bm = jax.random.normal(k[3], (B, S, N))
    Cm = jax.random.normal(k[4], (B, S, N))
    want, _ = ssd_chunked(xh, dt, A, Bm, Cm, 128)
    got = ops.ssd_scan(xh, dt, dt * A, Bm, Cm, chunk=128, interpret=True)
    assert jnp.max(jnp.abs(got - want)) < 5e-3


@pytest.mark.parametrize("B,d", [(128, 64), (256, 128), (384, 96)])
@pytest.mark.parametrize("tau", [0.2, 1.0])
def test_fused_info_nce(B, d, tau, rng):
    k1, k2 = jax.random.split(rng)
    q = jax.random.normal(k1, (B, d))
    k = jax.random.normal(k2, (B, d))
    got = ops.fused_info_nce(q, k, tau, interpret=True)
    want = info_nce(q, k, tau)
    assert abs(float(got) - float(want)) < 1e-4


@pytest.mark.parametrize("shape", [(256, 128), (4, 96, 256), (2, 3, 64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_rmsnorm(shape, dtype, rng):
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, shape, dtype)
    s = 1.0 + 0.1 * jax.random.normal(k2, (shape[-1],))
    got = ops.fused_rmsnorm(x, s, interpret=True)
    want = ref.rmsnorm_ref(x.reshape(-1, shape[-1]), s).reshape(shape)
    err = jnp.max(jnp.abs(got.astype(jnp.float32)
                          - want.astype(jnp.float32)))
    assert err < _tol(dtype)


# ---------------------------------------------------------------------------
# wire kernels (transport fast path): host / interpret engines vs oracle
# ---------------------------------------------------------------------------
WIRE_MODES = (None, True)


def _exact(interpret) -> bool:
    """Host mode is bit-exact vs the eager oracles; interpret mode gets
    the one-quantum int8 allowance (see module docstring)."""
    return ops._wire_mode(interpret) == "host"


def _wire_leaves(rng):
    """Three leaves + a layout mixing full slots and a partial (stacked
    stage range) slot, with deliberately unaligned sizes."""
    k = jax.random.split(rng, 3)
    leaves = [jax.random.normal(k[0], (4, 33)),        # stacked, partial
              jax.random.normal(k[1], (129,)),
              jax.random.normal(k[2], (7, 5))]
    # rows: (src_off, dst_off, size); leaf 0 ships rows 1..3 only
    layout = ((33, 0, 66), (0, 66, 129), (0, 195, 35))
    total = 230
    return leaves, layout, total


@pytest.mark.parametrize("interpret", WIRE_MODES)
def test_wire_pack_matches_ref(interpret, rng):
    leaves, layout, total = _wire_leaves(rng)
    got = np.asarray(ops.wire_pack(leaves, layout, total,
                                   interpret=interpret))
    want = np.asarray(ref.wire_pack_ref(
        [l.reshape(-1) for l in leaves], layout, total))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("interpret", WIRE_MODES)
def test_wire_unpack_matches_ref_and_roundtrips(interpret, rng):
    leaves, layout, total = _wire_leaves(rng)
    flat = jax.random.normal(jax.random.split(rng)[0], (total,))
    bases = [l.reshape(-1) for l in leaves]
    lay4 = tuple((s, d, n, n == b.shape[0])
                 for (s, d, n), b in zip(layout, bases))
    got = ops.wire_unpack(flat, bases, lay4, interpret=interpret)
    want = ref.wire_unpack_ref(jnp.asarray(flat), bases, layout)
    for g, w in zip(got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w))
    # pack(unpack(flat)) restores the wire buffer exactly
    repacked = ops.wire_pack(got, layout, total, interpret=interpret)
    assert np.array_equal(np.asarray(repacked), np.asarray(flat))


@pytest.mark.parametrize("interpret", WIRE_MODES)
def test_wire_cast_roundtrip(interpret, rng):
    flat = jax.random.normal(rng, (517,))
    for dtype in (jnp.float16, jnp.bfloat16):
        wire = ops.wire_cast_encode(flat, dtype, interpret=interpret)
        want = np.asarray(flat.astype(dtype))
        assert np.array_equal(np.asarray(wire), want)
        dec = ops.wire_cast_decode(wire, interpret=interpret)
        assert np.array_equal(np.asarray(dec),
                              np.asarray(want.astype(np.float32)))


@pytest.mark.parametrize("interpret", WIRE_MODES)
def test_wire_int8_matches_codec_math(interpret, rng):
    # two payload slots: a (64, 8) matrix (per-column scales) and a
    # 40-vector (single per-tensor scale)
    k1, k2 = jax.random.split(rng)
    a = jax.random.normal(k1, (64, 8)) * 3.0
    b = jax.random.normal(k2, (40,))
    flat = jnp.concatenate([a.reshape(-1), b])
    segs = ((0, 512, 8, 0), (512, 40, 1, 8))
    q, scales = ops.wire_int8_encode(flat, segs, 9, interpret=interpret)
    qa, sa = ref.int8_quant_ref(a)
    qb, sb = ref.int8_quant_ref(b.reshape(-1, 1))
    want_q = np.concatenate([np.asarray(qa).reshape(-1),
                             np.asarray(qb).reshape(-1)])
    want_s = np.concatenate([np.asarray(sa), np.asarray(sb)])
    if _exact(interpret):
        assert np.array_equal(np.asarray(q), want_q)
        assert np.array_equal(np.asarray(scales), want_s)
    else:
        assert np.abs(np.asarray(q).astype(np.int32)
                      - want_q.astype(np.int32)).max() <= 1
        np.testing.assert_allclose(np.asarray(scales), want_s, rtol=1e-6)
    dec = ops.wire_int8_decode(q, scales, segs, 552, interpret=interpret)
    want_dec = np.concatenate([
        np.asarray(ref.int8_dequant_ref(qa, sa)).reshape(-1),
        np.asarray(ref.int8_dequant_ref(qb, sb)).reshape(-1)])
    atol = 0.0 if _exact(interpret) else float(want_s.max()) * 1.01
    np.testing.assert_allclose(np.asarray(dec), want_dec, atol=atol)


@pytest.mark.parametrize("interpret", WIRE_MODES)
@pytest.mark.parametrize("with_res", [False, True])
def test_wire_topk_ef_matches_ref(interpret, with_res, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    n, k = 700, 70
    flat = jax.random.normal(k1, (n,))
    base = jax.random.normal(k2, (n,))
    res = jax.random.normal(k3, (n,)) * 0.1 if with_res else None
    idx, val, new_res = ops.wire_topk_encode_ef(flat, base, res, k,
                                                interpret=interpret)
    ridx, rval, rres, rdec = ref.topk_ef_ref(
        flat, base, jnp.zeros_like(flat) if res is None else res, k)
    # idx order is backend-specific (magnitude-sorted vs position-sorted):
    # the selected set, decoded payload and residual must match exactly
    assert sorted(np.asarray(idx).tolist()) == \
        sorted(np.asarray(ridx).tolist())
    dec = ops.wire_topk_decode(idx, val, n, interpret=interpret)
    assert np.array_equal(np.asarray(dec), np.asarray(rdec))
    assert np.array_equal(np.asarray(new_res), np.asarray(rres))


@pytest.mark.parametrize("interpret", WIRE_MODES)
def test_wire_topk_breaks_ties_like_top_k(interpret):
    # exact duplicated magnitudes straddling the threshold: selection must
    # keep lax.top_k's lowest-index-first tie order
    flat = jnp.asarray(
        np.tile(np.asarray([5.0, -3.0, 3.0, 1.0, 3.0, -5.0], np.float32),
                40))
    base = jnp.zeros_like(flat)
    k = 100          # 80 entries of |x|=5, threshold ties at |x|=3
    idx, val, new_res = ops.wire_topk_encode_ef(flat, base, None, k,
                                                interpret=interpret)
    ridx, rval, rres, rdec = ref.topk_ef_ref(flat, base,
                                             jnp.zeros_like(flat), k)
    assert sorted(np.asarray(idx).tolist()) == \
        sorted(np.asarray(ridx).tolist())
    dec = ops.wire_topk_decode(idx, val, flat.shape[0],
                               interpret=interpret)
    assert np.array_equal(np.asarray(dec), np.asarray(rdec))
    assert np.array_equal(np.asarray(new_res), np.asarray(rres))
