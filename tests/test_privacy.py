"""Privacy subsystem invariants: RDP accountant pinned against published
reference values, bit-exact pairwise-mask cancellation across the
vit / xlstm / zamba leaf families and all five schedules' payload specs,
DP-off / clip=inf bit-parity of both engines against the baseline driver,
dedicated-noise-stream determinism, FLHistory v1/v2 compatibility,
epsilon-budget halting, secure aggregation under the fleet simulator,
privacy attributes in obs round spans, and the privacy bench schema.
"""
import functools
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import FLConfig, ModelConfig, SSLConfig, TrainConfig
from repro.core import schedule as sched
from repro.data import iid_partition, synthetic_images
from repro.federated import aggregate, driver, fleet, simulation
from repro.federated.driver import FLHistory, run_fedssl
from repro.federated.transport import (Transport, build_payload_spec,
                                       pack_stage_payload)
from repro.obs import make_obs
from repro.privacy import (DEFAULT_ORDERS, MASK_ITEMSIZE, PrivacyConfig,
                           PrivacyEngine, RDPAccountant, SecureAggregator,
                           compute_epsilon, make_privacy,
                           rdp_sampled_gaussian, rdp_to_epsilon)
from test_transport import FAMILIES, family_tree

# ---------------------------------------------------------------------------
# accountant: pinned references and closed forms
# ---------------------------------------------------------------------------
def test_epsilon_pinned_references():
    """q=1, z=1, delta=1e-5: one round of the plain Gaussian mechanism
    gives eps = min_a a/2 + log(1e5)/(a-1) = 5.302585... (at a=6); 100
    rounds compose to 111.512925... (at a=2). Both are the standard
    moments-accountant reference values for these settings."""
    assert compute_epsilon(1.0, 1.0, 1, 1e-5) == pytest.approx(
        5.302585093, abs=1e-3)
    assert compute_epsilon(1.0, 1.0, 100, 1e-5) == pytest.approx(
        111.512925465, abs=1e-3)


def test_rdp_closed_forms():
    # q=1 collapses to the plain Gaussian mechanism a/(2 sigma^2)
    for a in (2, 5, 32):
        for s in (0.5, 1.0, 4.0):
            assert rdp_sampled_gaussian(1.0, s, a) == pytest.approx(
                a / (2 * s * s), rel=1e-12)
    # alpha=2 binomial sum has the textbook closed form
    q = 0.01
    want = math.log(1.0 + q * q * (math.e - 1.0))
    assert rdp_sampled_gaussian(q, 1.0, 2) == pytest.approx(want, rel=1e-9)
    assert rdp_sampled_gaussian(0.0, 1.0, 8) == 0.0
    assert rdp_sampled_gaussian(0.5, 0.0, 8) == math.inf


def test_rdp_validation():
    with pytest.raises(ValueError):
        rdp_sampled_gaussian(0.5, 1.0, 1)          # alpha < 2
    with pytest.raises(ValueError):
        rdp_sampled_gaussian(0.5, 1.0, 2.5)        # non-integer alpha
    with pytest.raises(ValueError):
        rdp_sampled_gaussian(1.5, 1.0, 2)          # q outside [0, 1]
    with pytest.raises(ValueError):
        rdp_to_epsilon([1.0], [2], 0.0)            # delta outside (0, 1)
    with pytest.raises(ValueError):
        RDPAccountant(-0.1)


def test_epsilon_monotone_and_amplified():
    e1 = compute_epsilon(0.1, 1.1, 10, 1e-5)
    e2 = compute_epsilon(0.1, 1.1, 100, 1e-5)
    assert 0.0 < e1 < e2                           # more rounds, more eps
    assert compute_epsilon(0.1, 2.0, 100, 1e-5) < e2   # more noise, less
    # subsampling amplification: q < 1 strictly beats full participation
    assert e2 < compute_epsilon(1.0, 1.1, 100, 1e-5)


def test_accountant_edges():
    acct = RDPAccountant(1.0)
    assert acct.epsilon(1e-5) == 0.0               # nothing observed yet
    acct.observe_round(0.5)
    assert math.isfinite(acct.epsilon(1e-5))
    zero = RDPAccountant(0.0)
    zero.observe_round(1.0)
    assert zero.epsilon(1e-5) == math.inf          # no noise, no guarantee


@given(q=st.floats(0.01, 0.99), sigma=st.floats(0.6, 4.0),
       alpha=st.integers(2, 40))
@settings(max_examples=30, deadline=None)
def test_rdp_nonnegative_finite(q, sigma, alpha):
    r = rdp_sampled_gaussian(q, sigma, alpha)
    assert 0.0 <= r < math.inf
    # subsampled mechanism never exceeds the q=1 Gaussian mechanism
    assert r <= alpha / (2 * sigma * sigma) + 1e-12


# ---------------------------------------------------------------------------
# secure aggregation: masks, fixed point, bit-exact cancellation
# ---------------------------------------------------------------------------
def test_pair_mask_shared_and_distinct():
    seed = (1, 2, 3)
    a = SecureAggregator.pair_mask(seed, 2, 5, 64)
    b = SecureAggregator.pair_mask(seed, 5, 2, 64)
    np.testing.assert_array_equal(a, b)            # both endpoints agree
    c = SecureAggregator.pair_mask(seed, 2, 6, 64)
    assert not np.array_equal(a, c)                # distinct per pair
    d = SecureAggregator.pair_mask((9, 2, 3), 2, 5, 64)
    assert not np.array_equal(a, d)                # distinct per round seed
    with pytest.raises(ValueError):
        SecureAggregator.pair_mask(seed, 3, 3, 64)


@given(fam=st.sampled_from(FAMILIES), seed=st.integers(0, 6))
@settings(max_examples=9, deadline=None)
def test_masks_cancel_bit_exact_across_families(fam, seed):
    """aggregate(mask=True) == aggregate(mask=False) to the bit: uint64
    modular arithmetic makes the pairwise masks telescope exactly out of
    the sum for every stacked-key leaf family."""
    tree, S = family_tree(fam, seed)
    spec = build_payload_spec(tree, (0, S), include_embed=True,
                              include_heads=True)
    rng = np.random.default_rng(seed)
    flats = [pack_stage_payload(tree, spec)
             * jnp.float32(1.0 + 0.1 * i) for i in range(3)]
    w = rng.dirichlet(np.ones(3))
    ids = [int(i) for i in rng.permutation(10)[:3]]
    agg = SecureAggregator()
    masked = agg.aggregate(flats, w, ids, (seed, 7), mask=True)
    plain = agg.aggregate(flats, w, ids, (seed, 7), mask=False)
    np.testing.assert_array_equal(masked, plain)
    # and the fixed-point sum tracks the float sum to quantization error
    ref = sum(np.asarray(f, np.float64) * wi for f, wi in zip(flats, w))
    np.testing.assert_allclose(masked, ref, atol=1e-6, rtol=0)


@pytest.mark.parametrize("schedule", sched.SCHEDULES)
def test_masks_cancel_for_every_schedule_spec(schedule):
    """Bit-exact cancellation on the actual upload payload spec of every
    round of all five schedules (specs differ in stage range / embed /
    head inclusion across schedules)."""
    tree, S = family_tree("vit", 0)
    fl = FLConfig(num_clients=3, rounds=max(4, S), local_epochs=1,
                  schedule=schedule)
    wire = Transport("fp32")
    agg = SecureAggregator()
    for plan in sched.build_schedule(fl, S):
        spec = wire.plan_specs(tree, plan)["upload"]
        flats = [pack_stage_payload(tree, spec) * jnp.float32(1.0 + 0.2 * i)
                 for i in range(3)]
        w = aggregate.client_weights([5, 7, 9])
        masked = agg.aggregate(flats, np.asarray(w), [0, 1, 2],
                               (42, plan.round_idx), mask=True)
        plain = agg.aggregate(flats, np.asarray(w), [0, 1, 2],
                              (42, plan.round_idx), mask=False)
        np.testing.assert_array_equal(masked, plain)


def test_secure_agg_validation():
    agg = SecureAggregator()
    x = [np.ones(4, np.float32)] * 2
    with pytest.raises(ValueError):
        agg.aggregate(x, [0.5, 0.5], [1, 1], (0,))      # duplicate ids
    with pytest.raises(ValueError):
        agg.aggregate(x, [1.0], [0, 1], (0,))           # length mismatch
    with pytest.raises(ValueError):
        agg.aggregate([], [], [], (0,))                 # empty
    with pytest.raises(ValueError):
        SecureAggregator(fraction_bits=60)
    with pytest.raises(ValueError):
        SecureAggregator(value_range=0.0)
    assert agg.masked_bytes(100) == 100 * MASK_ITEMSIZE


def test_quantize_clamps_to_value_range():
    agg = SecureAggregator(fraction_bits=10, value_range=2.0)
    q = agg.quantize(np.asarray([-5.0, 0.25, 5.0], np.float32), 1.0)
    out = agg.dequantize(q)
    np.testing.assert_allclose(out, [-2.0, 0.25, 2.0], atol=1e-3)


# ---------------------------------------------------------------------------
# PrivacyEngine configuration and streams
# ---------------------------------------------------------------------------
def test_make_privacy_gating():
    assert make_privacy(None) is None
    assert make_privacy(PrivacyConfig()) is None    # all features off
    eng = make_privacy(PrivacyConfig(clip=1.0))
    assert eng.dp and not eng.noise_enabled
    with pytest.raises(ValueError):
        make_privacy(PrivacyConfig(noise_multiplier=1.0))   # noise w/o clip
    with pytest.raises(ValueError):
        make_privacy(PrivacyConfig(clip=1.0, delta=1.5))
    with pytest.raises(TypeError):
        make_privacy({"clip": 1.0})


def test_round_keys_deterministic_per_round():
    key = jax.random.PRNGKey(3)
    s1, s2 = PrivacyEngine.fork_stream(key), PrivacyEngine.fork_stream(key)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    k0, m0 = PrivacyEngine.round_keys(s1, 0)
    k0b, m0b = PrivacyEngine.round_keys(s2, 0)
    np.testing.assert_array_equal(np.asarray(k0), np.asarray(k0b))
    assert m0 == m0b and isinstance(m0, tuple)
    k1, m1 = PrivacyEngine.round_keys(s1, 1)
    assert m0 != m1
    assert not np.array_equal(np.asarray(k0), np.asarray(k1))


def test_clip_paths_agree_and_pass_through():
    eng = make_privacy(PrivacyConfig(clip=0.5))
    rng = np.random.default_rng(0)
    ref = jnp.asarray(rng.normal(size=64), jnp.float32)
    flat = ref + jnp.asarray(rng.normal(size=64), jnp.float32)
    out_j, sc_j = eng.clip_jax(flat, ref)
    out_h, sc_h = eng.clip_host(np.asarray(flat), np.asarray(ref))
    np.testing.assert_allclose(np.asarray(out_j), out_h, atol=1e-6, rtol=0)
    assert float(sc_j) == pytest.approx(float(sc_h), rel=1e-6) and sc_h < 1.0
    norm = float(np.linalg.norm(np.asarray(out_j) - np.asarray(ref)))
    assert norm == pytest.approx(0.5, rel=1e-5)
    # below-threshold updates pass through bit-exactly on both paths
    wide = make_privacy(PrivacyConfig(clip=float("inf")))
    wj, wsj = wide.clip_jax(flat, ref)
    wh, wsh = wide.clip_host(np.asarray(flat), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(wj), np.asarray(flat))
    np.testing.assert_array_equal(wh, np.asarray(flat))
    assert float(wsj) == 1.0 and float(wsh) == 1.0


def test_sigma_scaling():
    eng = make_privacy(PrivacyConfig(clip=2.0, noise_multiplier=1.5))
    assert eng.noise_enabled
    assert eng.sigma(0.25) == pytest.approx(1.5 * 2.0 * 0.25)
    off = make_privacy(PrivacyConfig(clip=2.0))
    assert off.sigma(0.25) == 0.0


# ---------------------------------------------------------------------------
# driver integration (tiny vit runs, memoized across tests)
# ---------------------------------------------------------------------------
CFG = ModelConfig("t-vit", "dense", 2, 32, 2, 2, 64, 0, causal=False,
                  compute_dtype="float32", act="gelu")
SSLC = SSLConfig(proj_hidden=32, pred_hidden=32, proj_dim=16)
TC = TrainConfig(batch_size=16, base_lr=1.5e-4)
N_CLIENTS = 3
_IMAGES = jnp.asarray(
    np.random.default_rng(0).normal(size=(96, 32, 32, 3)), jnp.float32)
_INDICES = tuple(np.arange(i * 32, (i + 1) * 32) for i in range(N_CLIENTS))


@functools.lru_cache(maxsize=None)
def run_driver(engine="sequential", privacy=None, schedule="e2e", rounds=2,
               policy=None, profile="uniform", obs_trace=False):
    fl = FLConfig(num_clients=N_CLIENTS, rounds=rounds, local_epochs=1,
                  schedule=schedule)
    sim = None
    if policy is not None:
        sim = simulation.make_sim(
            fleet.make_fleet(profile, N_CLIENTS, seed=0), policy,
            num_clients=N_CLIENTS, seed=0)
    obs = make_obs(trace=True) if obs_trace else None
    state, hist = run_fedssl(
        CFG, SSLC, fl, TC, images=_IMAGES, client_indices=list(_INDICES),
        aux_images=_IMAGES[:16], key=jax.random.PRNGKey(0), engine=engine,
        privacy=privacy, sim=sim, obs=obs)
    return state, hist, obs


def _leaves(state):
    return [np.asarray(x) for x in jax.tree.leaves(state["online"])]


def assert_states_equal(a, b):
    for x, y in zip(_leaves(a), _leaves(b)):
        np.testing.assert_array_equal(x, y)


def max_state_delta(a, b):
    return max(float(np.max(np.abs(x - y)))
               for x, y in zip(_leaves(a), _leaves(b)))


@pytest.mark.parametrize("engine", ("sequential", "vmap"))
def test_dp_mode_off_is_bit_identical(engine):
    """clip=inf / noise=0 threads the whole privacy path (clip op, forked
    RNG stream, accountant) yet changes nothing: states bit-identical to
    the privacy=None baseline on both engines."""
    s0, h0, _ = run_driver(engine)
    for cfg in (PrivacyConfig(clip=float("inf")), PrivacyConfig(clip=1e9)):
        s1, h1, _ = run_driver(engine, cfg)
        assert_states_equal(s0, s1)
        assert h1.loss == h0.loss
        assert h1.epsilon == [math.inf, math.inf]   # honest: no noise
        assert h1.clip_fraction == [0.0, 0.0]
    assert h0.epsilon == [] and h0.clip_fraction == []


@pytest.mark.parametrize("engine", ("sequential", "vmap"))
def test_secure_agg_matches_float_fedavg(engine):
    """Secure aggregation (fp32 codec) tracks the float FedAvg baseline to
    fixed-point quantization error and records its wire overhead."""
    s0, _, _ = run_driver(engine)
    s2, h2, _ = run_driver(engine, PrivacyConfig(secure_agg=True))
    assert max_state_delta(s0, s2) < 1e-5
    assert len(h2.secure_agg_overhead_bytes) == 2
    assert all(b > 0 for b in h2.secure_agg_overhead_bytes)
    assert h2.epsilon == [math.inf, math.inf]       # secure-agg is not DP


@pytest.mark.slow
@pytest.mark.parametrize("schedule", sched.SCHEDULES)
def test_secure_agg_all_schedules(schedule):
    """Acceptance: --secure-agg with the fp32 codec stays within
    quantization error of the unmasked driver for all five schedules
    (bit-exactness of masked-vs-unmasked aggregation is the payload-level
    test above; this covers the full driver loop per schedule)."""
    s0, h0, _ = run_driver("sequential", None, schedule, 3)
    s1, h1, _ = run_driver("sequential", PrivacyConfig(secure_agg=True),
                           schedule, 3)
    assert max_state_delta(s0, s1) < 5e-5
    np.testing.assert_allclose(h0.loss, h1.loss, atol=1e-4, rtol=0)


def test_dp_run_deterministic_and_stream_isolated():
    """Same seed => bit-identical DP run (dedicated noise stream), and the
    noise stream never perturbs the training chain: round-0 losses match
    the baseline exactly (noise lands after the round's training)."""
    cfg = PrivacyConfig(clip=1.0, noise_multiplier=0.8)
    fl = FLConfig(num_clients=N_CLIENTS, rounds=2, local_epochs=1,
                  schedule="e2e")
    runs = [run_fedssl(CFG, SSLC, fl, TC, images=_IMAGES,
                       client_indices=list(_INDICES), aux_images=_IMAGES[:16],
                       key=jax.random.PRNGKey(0), privacy=cfg)
            for _ in range(2)]
    (sa, ha), (sb, hb) = runs
    assert ha.loss == hb.loss and ha.epsilon == hb.epsilon
    assert_states_equal(sa, sb)
    _, h0, _ = run_driver("sequential")
    assert ha.loss[0] == h0.loss[0]
    assert ha.loss[1] != h0.loss[1]                 # noise did something
    assert all(0.0 < e < math.inf for e in ha.epsilon)
    assert ha.epsilon[0] < ha.epsilon[1]            # composition grows eps


def test_noise_changes_state_but_noiseless_does_not():
    s_clip, _, _ = run_driver("sequential", PrivacyConfig(clip=1.0))
    s_dp, h_dp, _ = run_driver(
        "sequential", PrivacyConfig(clip=1.0, noise_multiplier=0.5))
    d = max_state_delta(s_clip, s_dp)
    assert np.isfinite(d) and d > 0.0
    assert all(np.isfinite(h_dp.loss))


def test_tight_clip_saturates_clip_fraction():
    _, h, _ = run_driver("sequential", PrivacyConfig(clip=1e-3))
    assert h.clip_fraction == [1.0, 1.0]


def test_epsilon_budget_halts_training():
    _, h, _ = run_driver(
        "sequential",
        PrivacyConfig(clip=1e-3, noise_multiplier=1.1, epsilon_budget=1.0),
        "e2e", 5)
    assert len(h.loss) < 5                          # stopped early
    assert h.epsilon[-1] > 1.0                      # because eps crossed it


@pytest.mark.parametrize("policy", ("deadline", "buffered-async"))
def test_secure_agg_with_fleet_policies(policy):
    """Survivor-set re-masking composes with deadline drops and async
    buffer flushes: runs complete with finite losses and record both the
    simulator accounting and the mask overhead."""
    _, h, _ = run_driver("sequential", PrivacyConfig(secure_agg=True),
                         "e2e", 3, policy, "pareto-stragglers")
    assert len(h.loss) == 3 and all(np.isfinite(h.loss))
    assert len(h.round_wall_clock) == 3
    assert all(b > 0 for b in h.secure_agg_overhead_bytes)


def test_traced_dp_round_spans_carry_privacy_attrs():
    _, h, obs = run_driver(
        "sequential", PrivacyConfig(clip=1.0, noise_multiplier=1.1,
                                    secure_agg=True), obs_trace=True)
    rounds = [e for e in obs.tracer.events if e["name"] == "round"]
    assert len(rounds) == 2
    for e, eps, ov in zip(rounds, h.epsilon, h.secure_agg_overhead_bytes):
        assert e["args"]["epsilon"] == pytest.approx(eps)
        assert e["args"]["secure_agg_overhead_bytes"] == ov
        assert "clip_fraction" in e["args"]


# ---------------------------------------------------------------------------
# FLHistory v2 schema
# ---------------------------------------------------------------------------
def test_history_v2_roundtrip_with_privacy_fields():
    _, h, _ = run_driver(
        "sequential", PrivacyConfig(clip=1.0, noise_multiplier=1.1,
                                    secure_agg=True))
    d = h.to_dict()
    assert d["version"] == driver.HISTORY_VERSION == 2
    back = FLHistory.from_dict(json.loads(json.dumps(d)))
    assert back.epsilon == h.epsilon
    assert back.clip_fraction == h.clip_fraction
    assert back.secure_agg_overhead_bytes == h.secure_agg_overhead_bytes
    # inf epsilons survive the JSON round trip too
    _, h_inf, _ = run_driver("sequential", PrivacyConfig(secure_agg=True))
    back_inf = FLHistory.from_dict(json.loads(json.dumps(h_inf.to_dict(),
                                                         allow_nan=True)))
    assert back_inf.epsilon == [math.inf, math.inf]


def test_history_v1_documents_still_load():
    _, h, _ = run_driver("sequential")
    d = h.to_dict()
    d["version"] = 1
    for name in ("epsilon", "clip_fraction", "secure_agg_overhead_bytes"):
        d["fields"].pop(name, None)
    back = FLHistory.from_dict(d)
    assert back.loss == h.loss
    assert back.epsilon == []                       # defaults fill in


def test_history_version_and_field_validation():
    _, h, _ = run_driver("sequential")
    bad = h.to_dict()
    bad["version"] = 3
    with pytest.raises(ValueError):
        FLHistory.from_dict(bad)
    bad2 = h.to_dict()
    bad2["fields"]["not_a_field"] = [1]
    with pytest.raises(ValueError):
        FLHistory.from_dict(bad2)


# ---------------------------------------------------------------------------
# benchmarks: privacy suite + --only list selection
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_bench_privacy_small_doc_validates():
    from benchmarks.run import bench_privacy
    from benchmarks.schemas import validate_privacy_bench
    doc = bench_privacy(rounds=2, clients=2, schedules=("e2e",),
                        codecs=("fp32",), write=False)
    assert validate_privacy_bench(doc) == []
    rows = doc["rows"]
    assert {r["codec"] for r in rows} == {"fp32"}
    dp_rows = [r for r in rows if r["dp"]]
    assert dp_rows and all(r["epsilon"] > 0 for r in dp_rows)
    assert any(r["secure_agg"] and r["mask_overhead_mb"] > 0 for r in rows)


def test_select_benches_comma_list():
    from benchmarks.run import _select_benches
    table = {"a": 1, "b": 2, "c": 3}
    assert list(_select_benches("a", table)) == ["a"]
    assert list(_select_benches("b, c", table)) == ["b", "c"]
    with pytest.raises(ValueError, match="unknown bench"):
        _select_benches("a,nope", table)
    with pytest.raises(ValueError):
        _select_benches(",,", table)


def test_validate_privacy_bench_cross_checks():
    from benchmarks.schemas import validate_privacy_bench
    row = dict(schedule="e2e", codec="fp32", dp=True, secure_agg=False,
               rounds=2, clients=2, final_loss=1.0, utility_delta=0.0,
               wire_mb=1.0, mask_overhead_mb=0.0, rounds_per_sec=1.0,
               slowdown=1.0, epsilon=None, clip_fraction=None)
    doc = {"bench": "privacy", "config": {}, "rows": [row]}
    assert any("epsilon" in p for p in validate_privacy_bench(doc))
    row["dp"], row["epsilon"], row["clip_fraction"] = False, 3.0, 0.5
    assert any("epsilon" in p for p in validate_privacy_bench(doc))
