"""Resource observatory + health monitoring + regression gating.

Covers the measured-resources module (``repro.obs.resources``): live
memory snapshots, the analytic-vs-XLA FLOPs cross-check on reduced
vit-tiny stages, and the compiled-program memory check; the streaming
``HealthMonitor`` (unit detectors + end-to-end NaN injection with
halt-on-fatal, and bit-identity of health-monitored runs on both
engines); golden-output tests for the trace CLI's round-time breakdown
and comm tables; the provenance header and resources/health schemas; and
the ``benchmarks.compare`` regression gate (drift detection, row
coverage, nonzero exit).
"""
import json
import math
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import compare as compare_mod
from benchmarks import schemas
from benchmarks.provenance import provenance
from repro.configs.base import FLConfig, ModelConfig, SSLConfig, TrainConfig
from repro.data import iid_partition, synthetic_images
from repro.federated.driver import run_fedssl
from repro.launch import trace as trace_cli
from repro.obs import HealthMonitor, make_obs, write_health_json
from repro.obs import resources as res_mod
from repro.obs.trace import Tracer
from repro.roofline.client_costs import PAPER_MULT

ROOT = pathlib.Path(__file__).resolve().parents[1]

CFG = ModelConfig("t-vit", "dense", 2, 32, 2, 2, 64, 0, causal=False,
                  compute_dtype="float32", act="gelu")
SSLC = SSLConfig(proj_hidden=32, pred_hidden=32, proj_dim=16)
TC = TrainConfig(batch_size=16, base_lr=1.5e-4)


def _run(engine="sequential", obs=None, rounds=2, schedule="lw_fedssl",
         images=None, seed=0):
    key = jax.random.PRNGKey(seed)
    if images is None:
        images, _ = synthetic_images(key, 96, 10, 32)
    idx = [jnp.asarray(i) for i in iid_partition(96, 3)]
    fl = FLConfig(num_clients=3, rounds=rounds, local_epochs=1,
                  schedule=schedule, server_epochs=1)
    return run_fedssl(CFG, SSLC, fl, TC, images=images, client_indices=idx,
                      aux_images=images[:16], key=key, engine=engine,
                      obs=obs)


# ---------------------------------------------------------------------------
# live memory watermarks + mem.* span-attr filtering
# ---------------------------------------------------------------------------
def test_device_memory_snapshot_cpu():
    snap = res_mod.device_memory_snapshot()
    assert snap["source"] in ("device", "rss")
    assert snap["bytes_in_use"] > 0
    assert snap["peak_bytes"] >= snap["bytes_in_use"] or \
        snap["source"] == "device"
    attrs = res_mod.memory_span_attrs()
    assert set(attrs) == {"mem.source", "mem.bytes_in_use",
                          "mem.peak_bytes"}


def test_structure_ignores_mem_attrs():
    """mem.* attrs vary per machine/run; the determinism fingerprint
    must not see them (the driver stamps them on every round span)."""
    tracers = []
    for peak in (111, 222):
        t = Tracer()
        with t.span("round", cat="fl", round=0) as sp:
            sp.set(loss=1.0)
            sp.set(**{"mem.source": "rss", "mem.bytes_in_use": peak,
                      "mem.peak_bytes": peak})
        tracers.append(t)
    assert tracers[0].structure() == tracers[1].structure()
    # the attrs themselves are still on the event for the trace readers
    assert tracers[0].events[0]["args"]["mem.peak_bytes"] == 111


# ---------------------------------------------------------------------------
# health monitor: unit detectors
# ---------------------------------------------------------------------------
def test_health_nonfinite_is_fatal_and_halts():
    m = HealthMonitor(halt_on_fatal=True)
    assert m.observe_round(0, loss=1.0) == []
    alerts = m.observe_round(1, loss=float("nan"))
    assert [a.kind for a in alerts] == ["loss_nonfinite"]
    assert alerts[0].level == "fatal"
    assert m.fatal and m.should_halt
    assert not HealthMonitor(halt_on_fatal=False).should_halt
    inf_alerts = m.observe_round(2, loss=float("inf"))
    assert inf_alerts[0].kind == "loss_nonfinite"
    assert inf_alerts[0].to_dict()["value"] is None      # json-safe


def test_health_loss_spike_zscore_and_stage_reset():
    m = HealthMonitor(loss_z=4.0, warmup=3)
    rng = np.random.RandomState(0)
    for i in range(8):
        assert m.observe_round(i, loss=1.0 + 1e-3 * rng.randn()) == []
    alerts = m.observe_round(8, loss=5.0)
    assert [a.kind for a in alerts] == ["loss_spike"]
    assert alerts[0].level == "warn" and alerts[0].value > 4.0
    # a new stage resets the distribution: the same jump right after a
    # stage transition is a new loss scale, not a spike
    assert m.observe_round(9, loss=5.0, new_stage=True) == []
    assert m.observe_round(10, loss=5.0) == []


def test_health_compression_drift_per_stage_reference():
    m = HealthMonitor(ratio_rtol=0.25)
    assert m.observe_round(0, loss=1.0, compression_ratio=4.0) == []
    assert m.observe_round(1, loss=1.0, compression_ratio=4.5) == []
    alerts = m.observe_round(2, loss=1.0, compression_ratio=8.0)
    assert [a.kind for a in alerts] == ["compression_drift"]
    # stage transition re-bases the reference ratio
    assert m.observe_round(3, loss=1.0, compression_ratio=8.0,
                           new_stage=True) == []


def test_health_drop_rate_and_recompile_storm():
    m = HealthMonitor(drop_rate_max=0.5, warmup=2)
    for i in range(2):       # inside warmup: never flagged
        assert m.observe_round(i, loss=1.0, dropped=2, participants=1) == []
    alerts = m.observe_round(2, loss=1.0, dropped=2, participants=1)
    assert [a.kind for a in alerts] == ["drop_rate"]
    # recompiles on a stage-opening round are legal retraces
    m2 = HealthMonitor()
    assert m2.observe_round(0, loss=1.0, recompiles=2, new_stage=True) == []
    alerts = m2.observe_round(1, loss=1.0, recompiles=1)
    assert [a.kind for a in alerts] == ["recompile_storm"]


def test_health_report_schema_and_export(tmp_path):
    m = HealthMonitor()
    m.observe_round(0, loss=1.0)
    m.observe_round(1, loss=float("nan"))
    rep = m.report()
    assert schemas.validate_health_report(rep) == []
    assert rep["counts"]["loss_nonfinite"] == 1 and rep["fatal"]
    out = tmp_path / "health.json"
    doc = write_health_json(out, m, schedule="lw_fedssl")
    reread = json.loads(out.read_text())
    assert schemas.validate_health_report(reread) == []
    assert reread["meta"]["schedule"] == "lw_fedssl" == \
        doc["meta"]["schedule"]
    # the validator catches cooked documents
    bad = dict(rep, counts=dict(rep["counts"], loss_spike=7))
    assert schemas.validate_health_report(bad) != []
    assert schemas.validate_health_report(
        dict(rep, fatal=False, halted=True)) != []


# ---------------------------------------------------------------------------
# health monitor: end-to-end through the driver
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_nan_injection_flags_and_halts(tmp_path):
    """A NaN-poisoned batch must raise a fatal loss_nonfinite alert on
    the trace, truncate the run under --halt-on-unhealthy, and export a
    schema-valid health.json."""
    imgs, _ = synthetic_images(jax.random.PRNGKey(0), 96, 10, 32)
    bad = np.asarray(imgs).copy()
    bad[:] = np.nan
    obs = make_obs(trace=True, health=True, halt_on_unhealthy=True)
    _, hist = _run(obs=obs, rounds=3, images=jnp.asarray(bad))
    assert len(hist.loss) == 1 and math.isnan(hist.loss[0])
    assert obs.health.fatal and obs.health.should_halt
    kinds = [e["name"] for e in obs.tracer.events if e["cat"] == "health"]
    assert "health.loss_nonfinite" in kinds and "health.halt" in kinds
    out = tmp_path / "health.json"
    obs.export(health_json=out, schedule="lw_fedssl")
    doc = json.loads(out.read_text())
    assert schemas.validate_health_report(doc) == []
    assert doc["halted"] is True
    # without the halt hook the run finishes all rounds, still flagged
    obs2 = make_obs(health=True)
    _, hist2 = _run(obs=obs2, rounds=3, images=jnp.asarray(bad))
    assert len(hist2.loss) == 3 and obs2.health.fatal
    assert not obs2.health.should_halt


@pytest.mark.slow
@pytest.mark.parametrize("engine", ["sequential", "vmap"])
def test_health_monitoring_is_bit_identical(engine):
    """The monitor observes host-side scalars only: a healthy run with
    health (+trace) enabled must train byte-identically to an
    unmonitored one, and raise nothing."""
    s_off, h_off = _run(engine=engine, obs=None)
    obs = make_obs(trace=True, health=True, halt_on_unhealthy=True)
    s_on, h_on = _run(engine=engine, obs=obs)
    assert obs.health.alerts == [] and not obs.health.fatal
    for a, b in zip(jax.tree.leaves(s_off["online"]),
                    jax.tree.leaves(s_on["online"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert h_off.loss == h_on.loss


# ---------------------------------------------------------------------------
# measured resources: analytic roofline vs XLA cost/memory analysis
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_flops_crosscheck_analytic_vs_xla():
    """Per-stage XLA cost_analysis FLOPs (unrolled lowering) must agree
    with the analytic roofline within FLOPS_RTOL on reduced vit-tiny
    stages — for both the layer-wise schedule's stage shapes and the
    vmap engine's fused round program."""
    cfg, ssl, train = res_mod.measurement_config(num_layers=2, batch_size=4)
    m = res_mod.measure_schedule("lw_fedssl", "sequential", cfg=cfg,
                                 ssl=ssl, train=train, rounds=4,
                                 compile_memory=False)
    assert len(m["stages"]) == 2 and m["peak_memory"] is None
    for s in m["stages"]:
        ratio = s["flops_per_sample"] / s["analytic_flops_per_sample"]
        assert abs(ratio - 1.0) <= res_mod.FLOPS_RTOL, s
        assert ratio >= 1.0    # XLA counts ops the roofline folds away
    assert abs(m["flops_total"] / m["analytic_flops_total"] - 1.0) \
        <= res_mod.FLOPS_RTOL
    mv = res_mod.measure_schedule("e2e", "vmap", cfg=cfg, ssl=ssl,
                                  train=train, rounds=2,
                                  compile_memory=False, clients=2)
    ratio = mv["flops_total"] / mv["analytic_flops_total"]
    assert abs(ratio - 1.0) <= res_mod.FLOPS_RTOL


@pytest.mark.slow
def test_memory_crosscheck_compiled_program():
    """Compiled-program peak bytes (memory_analysis of the rolled
    program) must land within MEMORY_FACTOR of the program-aware
    analytic prediction."""
    cfg, ssl, train = res_mod.measurement_config(num_layers=2, batch_size=4)
    m = res_mod.measure_schedule("e2e", "sequential", cfg=cfg, ssl=ssl,
                                 train=train, rounds=2,
                                 compile_memory=True)
    assert m["peak_memory"] and m["argument_bytes"] and m["output_bytes"]
    ratio = m["peak_memory"] / m["program_peak_analytic"]
    assert 1.0 / res_mod.MEMORY_FACTOR <= ratio <= res_mod.MEMORY_FACTOR, m


def test_unrolled_scans_restores_flag():
    from repro.models import scan_cfg
    assert scan_cfg.UNROLL is False
    with pytest.raises(RuntimeError):
        with res_mod.unrolled_scans():
            assert scan_cfg.UNROLL is True
            raise RuntimeError("boom")
    assert scan_cfg.UNROLL is False


# ---------------------------------------------------------------------------
# trace CLI: golden output for breakdown + comm tables
# ---------------------------------------------------------------------------
def _span(name, cat, dur, **args):
    return {"ph": "X", "name": name, "cat": cat, "ts": 0, "dur": dur,
            "pid": 0, "tid": 0, "seq": 0, "parent": None, "depth": 0,
            "args": args}


def test_breakdown_golden_output(capsys):
    events = [
        _span("run", "fl", 4_000_000, schedule="lw_fedssl",
              engine="sequential", codec="fp32"),
        _span("round", "fl", 2_000_000, round=0),
        _span("round", "fl", 2_000_000, round=1),
        _span("local_train", "fl", 1_500_000),
        _span("local_train", "fl", 1_500_000),
        _span("client", "sim", 9_000_000),      # virtual track: excluded
    ]
    trace_cli.print_breakdown("run.jsonl", events)
    assert capsys.readouterr().out == (
        "\n-- run.jsonl: schedule=lw_fedssl engine=sequential codec=fp32\n"
        "   span                      count      total       mean\n"
        "   run                           1     4.000s  4000.00ms\n"
        "   round                         2     4.000s  2000.00ms\n"
        "   local_train                   2     3.000s  1500.00ms\n")


def test_comm_table_golden_output(capsys):
    def trace(schedule, down, up):
        events = [
            _span("run", "fl", 1, schedule=schedule, codec="fp32"),
            _span("round", "fl", 1, download_bytes=down, upload_bytes=up,
                  wire_download_bytes=down, wire_upload_bytes=up),
        ]
        return {"schedule": schedule}, events

    rows = trace_cli.comm_table([trace("e2e", 10_000_000, 10_000_000),
                                 trace("layerwise", 1_000_000, 1_000_000)])
    trace_cli.print_comm_table(rows)
    out = capsys.readouterr().out
    assert out == (
        "\n== comm totals (from round spans) ==\n"
        "schedule     rounds   down(MB)     up(MB)   wire(MB)"
        "   down x     up x   comm x\n"
        "e2e               1       10.0       10.0       20.0"
        "     1.00     1.00     1.00\n"
        "layerwise         1        1.0        1.0        2.0"
        "     0.10     0.10     0.10\n"
        "(ratios vs the e2e trace — paper Table 3 comm column: "
        "layerwise 0.08, lw_fedssl 0.31, progressive 0.54)\n")


def test_fullscale_comm_matches_paper_column():
    """The abstract full-scale walk behind --paper-table reproduces the
    paper's comm multipliers to the printed precision."""
    e2e = trace_cli.fullscale_comm("e2e")
    for s in ("layerwise", "lw_fedssl", "progressive"):
        assert trace_cli.fullscale_comm(s) / e2e == pytest.approx(
            PAPER_MULT[s][2], abs=0.005), s


# ---------------------------------------------------------------------------
# provenance + resources bench schema
# ---------------------------------------------------------------------------
def test_provenance_header_validates():
    errs = []
    schemas._check_provenance({"provenance": provenance(seed=7)}, errs)
    assert errs == []
    errs = []
    schemas._check_provenance({}, errs)
    assert any("provenance" in e for e in errs)
    errs = []
    schemas._check_provenance(
        {"provenance": {"version": 1, "git_commit": 123}}, errs)
    assert any("git_commit" in e for e in errs)


def test_bench_validators_require_provenance():
    doc = {"bench": "simulation", "config": {}, "rows": [{}]}
    assert any("provenance" in e
               for e in schemas.validate_simulation_bench(doc))
    doc = {"bench": "privacy", "config": {}, "rows": [{}]}
    assert any("provenance" in e
               for e in schemas.validate_privacy_bench(doc))


def _resources_row(**over):
    row = {
        "engine": "sequential", "schedule": "e2e", "num_layers": 2,
        "batch_size": 4, "rounds": 2, "local_epochs": 1, "clients": 1,
        "stages": [{"sub_layers": 2, "active_from": 0, "align": False,
                    "depth_dropout": 0.0, "rounds": 2,
                    "flops_per_sample": 50.0,
                    "analytic_flops_per_sample": 50.0,
                    "analytic_memory_bytes": 1e6}],
        "flops_total": 100.0, "analytic_flops_total": 100.0,
        "analytic_peak_memory": 1e6, "program_peak_analytic": 1e6,
        "peak_memory": 1.5e6, "argument_bytes": 1e6,
        "output_bytes": 4e5, "temp_bytes": 1e5,
        "comm_bytes": 1000, "comm_ratio": 1.0,
        "analytic_flops_ratio": 1.0, "analytic_memory_ratio": 1.0,
        "flops_ratio": 1.0, "memory_ratio": 1.0,
    }
    row.update(over)
    return row


def _resources_doc(**over):
    return {"bench": "resources",
            "config": {"tolerances": {"flops_rtol": 0.30,
                                      "memory_factor": 3.0}},
            "rows": [_resources_row(**over)],
            "provenance": provenance(seed=0)}


def test_resources_bench_schema_enforces_tolerances():
    assert schemas.validate_resources_bench(_resources_doc()) == []
    # measured flops outside the documented rtol -> invalid document
    errs = schemas.validate_resources_bench(
        _resources_doc(flops_total=150.0))
    assert any("flops_total" in e and "outside" in e for e in errs)
    errs = schemas.validate_resources_bench(
        _resources_doc(peak_memory=9e6))
    assert any("peak_memory" in e and "outside" in e for e in errs)
    # flops-only documents (peak_memory null) are fine
    assert schemas.validate_resources_bench(_resources_doc(
        peak_memory=None, argument_bytes=None, output_bytes=None,
        temp_bytes=None, memory_ratio=None)) == []
    errs = schemas.validate_resources_bench(
        _resources_doc(unknown_field=1))
    assert any("unknown_field" in e for e in errs)


# ---------------------------------------------------------------------------
# regression gate: benchmarks.compare
# ---------------------------------------------------------------------------
def test_compare_passes_on_identical_docs():
    doc = _resources_doc()
    assert compare_mod.compare_docs("resources", doc, doc) == []


def test_compare_flags_metric_drift_and_row_coverage():
    base = _resources_doc()
    drifted = _resources_doc(flops_total=110.0)      # 10% > 5% rtol
    probs = compare_mod.compare_docs("resources", drifted, base)
    assert any("flops_total" in p and "drifted" in p for p in probs)
    # timing-free metrics within tolerance pass
    ok = _resources_doc(flops_total=101.0, peak_memory=1.6e6)
    assert compare_mod.compare_docs("resources", ok, base) == []
    # rows disappearing or appearing both gate
    two = dict(base, rows=base["rows"]
               + [_resources_row(schedule="layerwise")])
    assert any("coverage shrank" in p
               for p in compare_mod.compare_docs("resources", base, two))
    assert any("not in baseline" in p
               for p in compare_mod.compare_docs("resources", two, base))


def test_compare_nested_metric_paths():
    base = {"codecs": {"fp32": {"ratio": 1.0}, "int8": {"ratio": 4.0}}}
    vals = dict(compare_mod._lookup(base, "codecs.*.ratio"))
    assert vals == {"codecs.fp32.ratio": 1.0, "codecs.int8.ratio": 4.0}
    assert compare_mod._lookup({}, "codecs.*.ratio") \
        == [("codecs", KeyError)]


def test_compare_cli_exit_codes(tmp_path):
    r, b = tmp_path / "resources_bench.json", tmp_path / "base.json"
    b.write_text(json.dumps(_resources_doc()))
    r.write_text(json.dumps(_resources_doc()))
    assert compare_mod.main([str(r), str(b)]) == 0
    r.write_text(json.dumps(_resources_doc(flops_total=110.0)))
    assert compare_mod.main([str(r), str(b)]) == 1
    # directory mode: every baseline must have a results counterpart
    bdir = tmp_path / "baselines"
    bdir.mkdir()
    (bdir / "resources_bench.json").write_text(json.dumps(_resources_doc()))
    rdir = tmp_path / "results"
    rdir.mkdir()
    assert compare_mod.main(["--results-dir", str(rdir),
                             "--baselines-dir", str(bdir)]) == 1
    (rdir / "resources_bench.json").write_text(
        json.dumps(_resources_doc()))
    assert compare_mod.main(["--results-dir", str(rdir),
                             "--baselines-dir", str(bdir)]) == 0
    # schema-invalid results never pass the gate
    broken = _resources_doc()
    del broken["rows"][0]["comm_bytes"]
    (rdir / "resources_bench.json").write_text(json.dumps(broken))
    assert compare_mod.main(["--results-dir", str(rdir),
                             "--baselines-dir", str(bdir)]) == 1


# ---------------------------------------------------------------------------
# committed artifacts: results/ vs benchmarks/baselines/
# ---------------------------------------------------------------------------
def test_checked_in_resources_artifact_matches_baseline():
    res = ROOT / "results" / "resources_bench.json"
    base = ROOT / "benchmarks" / "baselines" / "resources_bench.json"
    if not res.exists() or not base.exists():
        pytest.skip("resources bench artifacts not generated yet")
    doc = json.loads(res.read_text())
    assert schemas.validate_resources_bench(doc) == []
    assert compare_mod.compare_files(res, base) == []
    rows = doc["rows"]
    assert {r["engine"] for r in rows} == {"sequential", "vmap"}
    assert len(rows) == 10                     # 5 schedules x 2 engines
    for r in rows:
        # acceptance: full-scale comm column matches the paper exactly
        assert r["comm_ratio"] == pytest.approx(
            PAPER_MULT[r["schedule"]][2], abs=0.005), r["schedule"]
        assert r["peak_memory"] is not None
