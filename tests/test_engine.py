"""Vectorized-engine invariants: the vmap engine must reproduce the
sequential reference (same seed => same losses / params / comm bytes),
including ragged shards and client subsampling; padded shard construction
and the host-side RNG replay behave as documented."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (FLConfig, ModelConfig, SSLConfig,
                                TrainConfig)
from repro.core import schedule as sched
from repro.data import iid_partition, synthetic_images
from repro.data.partition import stack_shards
from repro.federated import comm
from repro.federated.client import replay_batch_plan
from repro.federated.driver import run_fedssl
from repro.models import lm as lm_mod

CFG = ModelConfig("t-vit", "dense", 2, 32, 2, 2, 64, 0, causal=False,
                  compute_dtype="float32", act="gelu")
SSLC = SSLConfig(proj_hidden=32, pred_hidden=32, proj_dim=16)
TC = TrainConfig(batch_size=16, base_lr=1.5e-4)


def _run(engine, *, schedule="e2e", rounds=2, client_indices=None,
         samples=96, clients=3, **fl_kw):
    key = jax.random.PRNGKey(0)
    imgs, _ = synthetic_images(key, samples, 10, 32)
    if client_indices is None:
        client_indices = [jnp.asarray(i)
                          for i in iid_partition(samples, clients)]
    fl = FLConfig(num_clients=len(client_indices), rounds=rounds,
                  local_epochs=1, schedule=schedule, server_epochs=1,
                  **fl_kw)
    return run_fedssl(CFG, SSLC, fl, TC, images=imgs,
                      client_indices=client_indices,
                      aux_images=imgs[:16], key=key, engine=engine)


def _assert_state_close(s1, s2, atol=1e-4):
    for a, b in zip(jax.tree.leaves(s1["online"]),
                    jax.tree.leaves(s2["online"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol)


@pytest.fixture(scope="module")
def lw_runs():
    """One LW-FedSSL run per engine, shared by the parity and comm tests."""
    return {e: _run(e, schedule="lw_fedssl") for e in ("sequential", "vmap")}


# ---------------------------------------------------------------------------
# parity
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_vmap_matches_sequential_e2e():
    s_seq, h_seq = _run("sequential")
    s_v, h_v = _run("vmap")
    np.testing.assert_allclose(h_seq.loss, h_v.loss, atol=1e-4)
    _assert_state_close(s_seq, s_v)


@pytest.mark.slow
def test_vmap_matches_sequential_lw_fedssl(lw_runs):
    """Covers stage walking, alignment loss, server calibration."""
    s_seq, h_seq = lw_runs["sequential"]
    s_v, h_v = lw_runs["vmap"]
    assert h_seq.round_stage == h_v.round_stage == [1, 2]
    np.testing.assert_allclose(h_seq.loss, h_v.loss, atol=1e-4)
    _assert_state_close(s_seq, s_v)


@pytest.mark.slow
def test_vmap_parity_ragged_and_subsampled():
    """Non-divisible shards (40/24/16 @ batch 16 => 2/1/1 local steps) and
    clients_per_round < num_clients: padded steps must be true no-ops."""
    idx = [jnp.arange(0, 40), jnp.arange(40, 64), jnp.arange(64, 80)]
    kw = dict(client_indices=idx, samples=80, clients_per_round=2)
    s_seq, h_seq = _run("sequential", **kw)
    s_v, h_v = _run("vmap", **kw)
    np.testing.assert_allclose(h_seq.loss, h_v.loss, atol=1e-4)
    _assert_state_close(s_seq, s_v)


# ---------------------------------------------------------------------------
# communication accounting
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_comm_identical_across_engines(lw_runs):
    _, h_seq = lw_runs["sequential"]
    _, h_v = lw_runs["vmap"]
    assert h_seq.download_bytes == h_v.download_bytes
    assert h_seq.upload_bytes == h_v.upload_bytes


def test_comm_lw_fedssl_savings_vs_e2e(rng):
    """Per stage, LW-FedSSL exchanges at most what e2e does, its upload is
    one constant block, and the absolute download saving over e2e shrinks
    monotonically as stages accumulate (paper Fig. 5c/5d)."""
    cfg = ModelConfig("t", "dense", 6, 32, 2, 2, 64, 50,
                      compute_dtype="float32")
    params = lm_mod.init_lm(rng, cfg)
    e2e_plan = sched.build_schedule(FLConfig(rounds=2, schedule="e2e"), 6)[0]
    e2e = comm.round_comm_bytes(params, e2e_plan, include_heads=False)
    plans = sched.build_schedule(FLConfig(rounds=6, schedule="lw_fedssl"), 6)
    assert [p.stage for p in plans] == [1, 2, 3, 4, 5, 6]
    savings = []
    for p in plans:
        cb = comm.round_comm_bytes(params, p, include_heads=False)
        assert cb["download"] <= e2e["download"]
        assert cb["upload"] < e2e["upload"]
        savings.append(e2e["download"] - cb["download"])
    assert all(a >= b for a, b in zip(savings, savings[1:]))
    assert savings[0] > savings[-1]
    # upload is a single block from stage 2 on
    ups = [comm.round_comm_bytes(params, p, include_heads=False)["upload"]
           for p in plans]
    assert len(set(ups[1:])) == 1


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------
def test_stack_shards_wrap_padding():
    pool = jnp.arange(10, dtype=jnp.int32) * 10
    stacked, lengths = stack_shards(pool, [np.arange(4), np.arange(4, 10)])
    assert stacked.shape == (2, 6) and list(lengths) == [4, 6]
    np.testing.assert_array_equal(np.asarray(stacked[1]),
                                  np.arange(4, 10) * 10)
    # ragged shard wraps around its own samples
    np.testing.assert_array_equal(np.asarray(stacked[0]),
                                  np.array([0, 10, 20, 30, 0, 10]))
    # pytree pools stack leaf-wise
    tree, _ = stack_shards({"a": pool, "b": pool + 1},
                           [np.arange(4), np.arange(4, 10)])
    np.testing.assert_array_equal(np.asarray(tree["a"]) + 1,
                                  np.asarray(tree["b"]))


def test_replay_batch_plan_matches_local_train_chain():
    key = jax.random.PRNGKey(7)
    n, bs, epochs, total = 40, 16, 2, 6
    idx, keys, valid = replay_batch_plan(key, n, epochs, bs, total)
    assert idx.shape == (total, bs) and keys.shape == (total, 2)
    assert list(valid) == [True] * 4 + [False] * 2      # nb = 2 per epoch
    # replicate local_train's chain by hand
    k = key
    k, kp = jax.random.split(k)
    perm = np.asarray(jax.random.permutation(kp, n))
    k, kb = jax.random.split(k)
    np.testing.assert_array_equal(idx[0], perm[:bs])
    np.testing.assert_array_equal(keys[0], np.asarray(kb))
    # each epoch's batches are disjoint slices of one permutation
    assert len(set(np.asarray(idx[:2]).ravel())) == 2 * bs


def test_lm_multi_client_round_program():
    """steps.make_fl_round_program: one program == per-client loop + fedavg."""
    from repro.data.synthetic import synthetic_tokens
    from repro.federated import aggregate
    from repro.launch.steps import make_fl_round_program, make_train_step

    cfg = ModelConfig("t", "dense", 2, 32, 2, 2, 64, 50,
                      compute_dtype="float32")
    tc = TrainConfig(batch_size=8, base_lr=1e-3)
    key = jax.random.PRNGKey(0)
    toks, labs = synthetic_tokens(key, 32, 16, cfg.vocab_size)
    params = lm_mod.init_lm(key, cfg)
    shards = [np.arange(0, 16), np.arange(16, 32)]
    stacked, _ = stack_shards({"tokens": toks, "labels": labs},
                              [jnp.asarray(s) for s in shards])
    prog, opt = make_fl_round_program(cfg, tc)   # lr passed live per round
    C, T, B = 2, 2, tc.batch_size
    batch_idx = jnp.asarray(
        np.stack([[np.arange(0, B), np.arange(B, 2 * B)]] * C))
    out, losses = prog(
        {"params": params}, stacked, batch_idx,
        jnp.zeros((C, T, 2), jnp.uint32), jnp.ones((C, T), bool),
        aggregate.client_weights([16, 16]), jnp.float32(1e-3))
    assert losses.shape == (C,) and np.isfinite(np.asarray(losses)).all()
    # reference: run the same two clients sequentially and average
    step, _ = make_train_step(cfg, tc, lr=1e-3)
    outs = []
    for ci in range(C):
        p, o = jax.tree.map(jnp.asarray, params), opt.init(params)
        for t in range(T):
            sel = shards[ci][t * B:(t + 1) * B]
            p, o, m = step(p, o, {"tokens": toks[sel], "labels": labs[sel]})
        outs.append(p)
    want = aggregate.fedavg(outs, aggregate.client_weights([16, 16]))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
