"""Observability subsystem (repro.obs): span tracer semantics and
determinism, bit-identity of traced vs untraced training on both engines,
exporter schemas (JSONL / Chrome trace_event / metrics CSV), FLHistory's
versioned JSON round-trip, and the trace CLI's reproduction of the
paper's per-schedule comm ratios from traces alone.
"""
import io
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import schemas
from repro.configs.base import FLConfig, ModelConfig, SSLConfig, TrainConfig
from repro.data import iid_partition, synthetic_images
from repro.federated import simulation as sim_mod
from repro.federated.driver import FLHistory, HISTORY_VERSION, run_fedssl
from repro.launch import trace as trace_cli
from repro.obs import (NOOP_OBS, ConsoleRenderer, chrome_trace_doc,
                       format_round_line, make_obs, metrics_csv_text,
                       read_jsonl, write_chrome_trace, write_jsonl)
from repro.obs.core import Observability
from repro.obs.metrics import NOOP_METRICS, MetricsRegistry
from repro.obs.trace import NOOP_TRACER, Tracer, is_tracing

CFG = ModelConfig("t-vit", "dense", 2, 32, 2, 2, 64, 0, causal=False,
                  compute_dtype="float32", act="gelu")
SSLC = SSLConfig(proj_hidden=32, pred_hidden=32, proj_dim=16)
TC = TrainConfig(batch_size=16, base_lr=1.5e-4)

# paper Table 3 comm multipliers vs FedMoCo (e2e); tolerance matches
# tests/test_federated.py's analytic-cost check
PAPER_COMM = {"e2e": 1.00, "layerwise": 0.08, "lw_fedssl": 0.31,
              "progressive": 0.54, "fll_dd": 0.08}


def _run(engine="sequential", obs=None, rounds=2, schedule="lw_fedssl",
         sim=None, seed=0):
    key = jax.random.PRNGKey(seed)
    imgs, _ = synthetic_images(key, 96, 10, 32)
    idx = [jnp.asarray(i) for i in iid_partition(96, 3)]
    fl = FLConfig(num_clients=3, rounds=rounds, local_epochs=1,
                  schedule=schedule, server_epochs=1)
    return run_fedssl(CFG, SSLC, fl, TC, images=imgs, client_indices=idx,
                      aux_images=imgs[:16], key=key, engine=engine,
                      sim=sim, obs=obs)


@pytest.fixture(scope="module")
def traced_run():
    """One traced+metered run shared by the exporter/schema tests."""
    obs = make_obs(trace=True, metrics=True, mode="test")
    state, hist = _run(obs=obs)
    return obs, state, hist


# ---------------------------------------------------------------------------
# tracer semantics
# ---------------------------------------------------------------------------
def test_span_nesting_and_attrs():
    t = Tracer()
    with t.span("run", cat="fl", mode="x"):
        with t.span("round", cat="fl", round=0) as r:
            with t.span("download", cat="fl"):
                pass
            r.set(loss=1.5)
        t.instant("marker", cat="fl", stage=2)
    names = [e["name"] for e in t.events]
    # children close before parents -> appear first in the event stream
    assert names == ["download", "round", "marker", "run"]
    by_name = {e["name"]: e for e in t.events}
    assert by_name["round"]["parent"] == by_name["run"]["seq"]
    assert by_name["download"]["parent"] == by_name["round"]["seq"]
    assert by_name["download"]["depth"] == 2      # run=0, round=1
    assert by_name["round"]["args"] == {"round": 0, "loss": 1.5}
    assert by_name["marker"]["ph"] == "i"
    assert by_name["marker"]["parent"] == by_name["run"]["seq"]
    for e in t.events:
        assert e["dur"] >= 0.0


def test_virtual_tracks_get_distinct_tids():
    t = Tracer()
    t.virtual_span("c0 r0", "sim client 0", 0.0, 1.0, client=0)
    t.virtual_span("c1 r0", "sim client 1", 0.0, 2.0, client=1)
    t.virtual_span("c0 r1", "sim client 0", 1.0, 1.0, client=0)
    tids = {e["tid"] for e in t.events}
    assert len(tids) == 2 and 0 not in tids       # 0 is the main track
    assert t.tracks["sim client 0"] != t.tracks["sim client 1"]
    # caller-supplied virtual timestamps, in microseconds
    assert t.events[2]["ts"] == pytest.approx(1e6)
    assert t.events[1]["dur"] == pytest.approx(2e6)


def test_noop_surfaces_do_nothing():
    assert not is_tracing(NOOP_TRACER)
    with NOOP_TRACER.span("x") as sp:
        sp.set(a=1)
    NOOP_TRACER.instant("y")
    NOOP_TRACER.virtual_span("z", "trk", 0.0, 1.0)
    assert NOOP_TRACER.events == [] and NOOP_TRACER.structure() == []
    NOOP_METRICS.counter("c").inc()
    NOOP_METRICS.gauge("g").set(3)
    NOOP_METRICS.histogram("h").observe(1.0)
    assert not NOOP_OBS.enabled
    assert NOOP_OBS.export(trace_jsonl="/nonexistent/x.jsonl") == {}


def test_make_obs_enablement():
    assert not make_obs().enabled
    assert make_obs(trace=True).enabled
    assert make_obs(metrics=True).enabled
    o = make_obs(trace=True, run="r1")
    assert is_tracing(o.tracer) and o.tracer.meta["run"] == "r1"
    assert isinstance(Observability(), type(NOOP_OBS))


# ---------------------------------------------------------------------------
# driver integration: determinism + bit-identity
# ---------------------------------------------------------------------------
def test_trace_structure_deterministic_across_runs():
    """Same seed -> identical timestamp-free span structure (ordering,
    nesting, names and attrs), on both engines."""
    for engine in ("sequential", "vmap"):
        o1, o2 = (make_obs(trace=True) for _ in range(2))
        _run(engine=engine, obs=o1)
        _run(engine=engine, obs=o2)
        s1, s2 = o1.tracer.structure(), o2.tracer.structure()
        assert s1 == s2
        assert any(ev[3] == "round" for ev in s1)


@pytest.mark.parametrize("engine", ["sequential", "vmap"])
def test_observability_is_bit_identical(engine):
    """Tracing+metrics is host-side only: the trained fp32 state must be
    byte-identical with obs fully enabled, no-op, and absent."""
    s_off, h_off = _run(engine=engine, obs=None)
    s_noop, _ = _run(engine=engine, obs=NOOP_OBS)
    s_on, h_on = _run(engine=engine,
                      obs=make_obs(trace=True, metrics=True))
    for a, b, c in zip(jax.tree.leaves(s_off["online"]),
                       jax.tree.leaves(s_noop["online"]),
                       jax.tree.leaves(s_on["online"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.array_equal(np.asarray(a), np.asarray(c))
    assert h_off.loss == h_on.loss


def test_metrics_agree_with_history(traced_run):
    obs, _, hist = traced_run
    d = obs.metrics.to_dict()
    assert d["counters"]["fl.rounds"] == len(hist.loss)
    assert d["counters"]["comm.download_bytes"] == sum(hist.download_bytes)
    assert d["counters"]["wire.upload_bytes"] == sum(hist.wire_upload_bytes)
    assert d["counters"]["jit.recompiles"] > 0          # first round compiles
    assert d["histograms"]["round.loss"]["count"] == len(hist.loss)
    assert d["gauges"]["wire.compression_ratio"] == pytest.approx(
        hist.compression_ratio)


def test_round_span_bytes_match_history(traced_run):
    obs, _, hist = traced_run
    rounds = [e for e in obs.tracer.events if e["name"] == "round"]
    rounds.sort(key=lambda e: e["args"]["round"])
    assert [e["args"]["download_bytes"] for e in rounds] \
        == hist.download_bytes
    assert [e["args"]["wire_upload_bytes"] for e in rounds] \
        == hist.wire_upload_bytes
    # fp32 identity codec: wire == analytic, per round
    assert [e["args"]["wire_download_bytes"] for e in rounds] \
        == hist.download_bytes


def test_simulation_emits_virtual_client_tracks():
    sim = sim_mod.make_sim("uniform", "synchronous", num_clients=3, seed=0)
    obs = make_obs(trace=True)
    _run(obs=obs, sim=sim)
    tracks = obs.tracer.tracks
    assert any(name.startswith("sim client") for name in tracks)
    virt = [e for e in obs.tracer.events if e["cat"] == "sim"
            and e["ph"] == "X"]
    assert virt and all("energy_j" in e["args"] for e in virt)
    assert any(e["name"].startswith("policy.") for e in obs.tracer.events
               if e["ph"] == "i")


# ---------------------------------------------------------------------------
# exporters + schemas
# ---------------------------------------------------------------------------
def test_jsonl_roundtrip_and_schema(tmp_path, traced_run):
    obs, _, _ = traced_run
    p = write_jsonl(obs.tracer, tmp_path / "t.jsonl", schedule="lw_fedssl")
    header, events = read_jsonl(p)
    assert schemas.validate_trace_jsonl(header, events) == []
    assert header["schedule"] == "lw_fedssl"
    assert events == obs.tracer.events
    with pytest.raises(ValueError):
        bad = tmp_path / "bad.jsonl"
        bad.write_text('{"kind": "something-else"}\n')
        read_jsonl(bad)


def test_chrome_trace_schema(tmp_path, traced_run):
    obs, _, _ = traced_run
    doc = chrome_trace_doc(obs.tracer)
    assert schemas.validate_chrome_trace(doc) == []
    p = write_chrome_trace(obs.tracer, tmp_path / "t.chrome.json")
    assert schemas.validate_chrome_trace(json.loads(p.read_text())) == []
    # the validator actually catches malformed documents
    assert schemas.validate_chrome_trace({}) != []
    assert schemas.validate_chrome_trace(
        {"traceEvents": [{"ph": "X", "name": "n", "cat": "c", "ts": 0,
                          "pid": 0, "tid": 0, "args": {}}],
         "displayTimeUnit": "ms"}) != []          # X without dur
    assert schemas.validate_chrome_trace(
        {"traceEvents": [{"ph": "i", "name": "n", "cat": "c", "ts": 0,
                          "pid": 0, "tid": 0, "args": {}}],
         "displayTimeUnit": "ms"}) != []          # instant without scope


def test_metrics_csv_schema(traced_run):
    obs, _, _ = traced_run
    text = metrics_csv_text(obs.metrics)
    assert schemas.validate_metrics_csv(text) == []
    assert schemas.validate_metrics_csv("not,a,header\n") != []
    assert schemas.validate_metrics_csv(
        "metric,type,field,value\nm,counter,oops,1\n") != []
    assert schemas.validate_metrics_csv(
        "metric,type,field,value\nm,counter,value,NaNope\n") != []


def test_obs_export_writes_requested_artifacts(tmp_path, traced_run):
    obs, _, _ = traced_run
    written = obs.export(trace_jsonl=tmp_path / "a.jsonl",
                         chrome_trace=tmp_path / "a.chrome.json",
                         metrics_csv=tmp_path / "a.csv")
    assert set(written) == {"trace_jsonl", "chrome_trace", "metrics_csv"}
    for p in written.values():
        assert p.exists() and p.stat().st_size > 0


# ---------------------------------------------------------------------------
# console renderer (shared round-line formatter)
# ---------------------------------------------------------------------------
def test_format_round_line():
    line = format_round_line(0, 12, 1, 5.1234, lr=1.5e-4, down_mb=0.5,
                             up_mb=0.25, wire_mb=0.75)
    assert line == ("round 1/12 stage 1 loss 5.1234 lr 1.50e-04 "
                    "down 0.50MB up 0.25MB wire 0.75MB")
    assert format_round_line(2, 4, 2, 1.0) == "round 3/4 stage 2 loss 1.0000"


def test_console_renderer_modes():
    buf = io.StringIO()
    r = ConsoleRenderer(stream=buf)
    r("one"); r("two"); r.close()
    assert buf.getvalue() == "one\ntwo\n"
    buf = io.StringIO()
    with ConsoleRenderer(live=True, stream=buf) as r:
        r("a long status line")
        r("short")
    out = buf.getvalue()
    assert out.startswith("\ra long status line\rshort")
    assert out.endswith("\n")                     # close() terminates
    # the shorter line is padded over the longer one
    assert len(out.split("\r")[2]) >= len("a long status line")


# ---------------------------------------------------------------------------
# FLHistory round-trip + NaN regression
# ---------------------------------------------------------------------------
def test_history_empty_compression_ratio_is_nan():
    assert math.isnan(FLHistory().compression_ratio)


def test_history_json_roundtrip():
    h = FLHistory(loss=[2.0, 1.5], round_stage=[1, 2],
                  download_bytes=[10, 20], upload_bytes=[10, 20],
                  wire_download_bytes=[5, 10], wire_upload_bytes=[5, 10],
                  round_wall_clock=[1.0, 2.0], device_seconds=[2.0, 4.0],
                  energy_joules=[0.5, 0.6], dropped_clients=[0, 1],
                  participants=[(0, 1), (1, 2)])
    d = json.loads(json.dumps(h.to_dict()))
    assert d["version"] == HISTORY_VERSION
    h2 = FLHistory.from_dict(d)
    assert h2 == h
    assert h2.participants == [(0, 1), (1, 2)]    # tuples restored
    assert h2.compression_ratio == pytest.approx(2.0)
    with pytest.raises(ValueError):
        FLHistory.from_dict({"version": 999, "fields": {}})
    with pytest.raises(ValueError):
        FLHistory.from_dict({"version": HISTORY_VERSION,
                             "fields": {"nope": []}})


def test_traced_history_roundtrips(traced_run):
    _, _, hist = traced_run
    assert FLHistory.from_dict(
        json.loads(json.dumps(hist.to_dict()))) == hist


# ---------------------------------------------------------------------------
# trace CLI: the paper's comm table from traces alone
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_comm_dryrun_traces_reproduce_paper_ratios(tmp_path):
    """--emit-comm walks the full 180-round vit-tiny schedules through the
    real Transport accounting; the analysis CLI's comm table must land on
    the paper's per-schedule upload/download multipliers, and fp32 wire
    bytes must equal comm.round_comm_bytes exactly in every round."""
    traces = []
    for s in PAPER_COMM:
        p = trace_cli.emit_comm_trace(s, tmp_path / f"{s}.jsonl")
        header, events = read_jsonl(p)
        assert schemas.validate_trace_jsonl(header, events) == []
        for e in trace_cli.round_spans(events):    # fp32: wire == analytic
            assert e["args"]["wire_download_bytes"] \
                == e["args"]["download_bytes"]
            assert e["args"]["wire_upload_bytes"] \
                == e["args"]["upload_bytes"]
        traces.append((header, events))
    rows = {r["schedule"]: r for r in trace_cli.comm_table(traces)}
    for s, want in PAPER_COMM.items():
        assert rows[s]["rounds"] == 180
        assert rows[s]["comm_ratio"] == pytest.approx(want, abs=0.06), s


def test_trace_cli_analyzes_live_trace(tmp_path, capsys, traced_run):
    obs, _, _ = traced_run
    p = write_jsonl(obs.tracer, tmp_path / "run.jsonl")
    trace_cli.main([str(p)])
    out = capsys.readouterr().out
    assert "comm totals" in out and "lw_fedssl" in out
    assert "round" in out                          # breakdown table
