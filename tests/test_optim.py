"""Optimizers + LR schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import TrainConfig
from repro.optim import make_optimizer
from repro.optim.optimizers import make_adafactor, make_adamw, make_sgdm
from repro.optim.schedules import learning_rate, scaled_base_lr


def test_adamw_first_step_direction(rng):
    opt = make_adamw(weight_decay=0.0)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 2.0)}
    st_ = opt.init(p)
    p2, _ = opt.update(g, st_, p, 0.1)
    # first Adam step ~= -lr * sign(g)
    assert np.allclose(np.asarray(p2["w"]), 1.0 - 0.1, atol=1e-3)


def test_adamw_weight_decay_moves_params():
    opt = make_adamw(weight_decay=0.1)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.zeros((4,))}
    st_ = opt.init(p)
    p2, _ = opt.update(g, st_, p, 0.1)
    assert float(p2["w"][0]) < 1.0


def test_mask_freezes_updates():
    opt = make_adamw(weight_decay=0.1)
    p = {"w": jnp.ones((4,)), "f": jnp.ones((4,))}
    g = {"w": jnp.ones((4,)), "f": jnp.ones((4,))}
    mask = {"w": jnp.float32(1.0), "f": jnp.float32(0.0)}
    st_ = opt.init(p)
    p2, _ = opt.update(g, st_, p, 0.1, mask)
    assert jnp.allclose(p2["f"], 1.0)           # frozen untouched
    assert not jnp.allclose(p2["w"], 1.0)


def test_grad_clip_limits_step():
    opt = make_adamw(grad_clip=1e-3)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 1e6)}
    st_ = opt.init(p)
    p2, _ = opt.update(g, st_, p, 1e-3)
    assert jnp.all(jnp.isfinite(p2["w"]))


def test_adafactor_factored_state_shapes():
    opt = make_adafactor()
    p = {"big": jnp.ones((256, 512)), "small": jnp.ones((4,))}
    st_ = opt.init(p)
    assert st_["m"]["big"]["vr"].shape == (256,)
    assert st_["m"]["big"]["vc"].shape == (512,)
    assert st_["m"]["small"]["v"].shape == (4,)
    g = jax.tree.map(jnp.ones_like, p)
    p2, st2 = opt.update(g, st_, p, 0.01)
    assert all(jnp.isfinite(x).all() for x in jax.tree.leaves(p2))


def test_adafactor_reduces_loss(rng):
    opt = make_adafactor()
    w_true = jax.random.normal(rng, (16, 1))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y = x @ w_true
    p = {"w": jnp.zeros((16, 1))}
    st_ = opt.init(p)

    def loss(p):
        return jnp.mean((x @ p["w"] - y) ** 2)

    l0 = float(loss(p))
    for _ in range(50):
        g = jax.grad(loss)(p)
        p, st_ = opt.update(g, st_, p, 0.1)
    assert float(loss(p)) < 0.5 * l0


def test_sgdm(rng):
    opt = make_sgdm(momentum=0.9)
    p = {"w": jnp.ones((4,))}
    st_ = opt.init(p)
    p2, st2 = opt.update({"w": jnp.ones((4,))}, st_, p, 0.1)
    assert jnp.allclose(p2["w"], 0.9)


def test_make_optimizer_dispatch():
    for name in ("adamw", "adafactor", "sgdm"):
        make_optimizer(TrainConfig(optimizer=name))
    with pytest.raises(ValueError):
        make_optimizer(TrainConfig(optimizer="nope"))


@given(total=st.integers(10, 500), base=st.floats(1e-5, 1e-2))
@settings(max_examples=20, deadline=None)
def test_cosine_decays_to_zero(total, base):
    assert float(learning_rate(0, total, base, "cosine")) == pytest.approx(
        base, rel=1e-5)
    assert float(learning_rate(total, total, base, "cosine")) < 1e-6
    mid = float(learning_rate(total // 2, total, base, "cosine"))
    assert 0 < mid < base


def test_fixed_and_cyclic():
    assert float(learning_rate(7, 10, 1e-3, "fixed")) == pytest.approx(1e-3)
    # cyclic restarts at each stage
    early = float(learning_rate(100, 180, 1e-3, "cyclic",
                                stage_step=0, stage_total=15))
    late = float(learning_rate(100, 180, 1e-3, "cyclic",
                               stage_step=14, stage_total=15))
    assert early == pytest.approx(1e-3, rel=1e-4)
    assert late < early


def test_lr_scaling_rule():
    assert scaled_base_lr(1.5e-4, 1024) == pytest.approx(6e-4)


def test_warmup():
    lr = learning_rate(5, 100, 1e-3, "fixed", warmup_steps=10)
    assert float(lr) == pytest.approx(5e-4)
