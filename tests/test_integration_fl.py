"""End-to-end FL integration: the five schedules run, losses decrease,
resource orderings match the paper's qualitative claims."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import (FLConfig, ModelConfig, SSLConfig,
                                TrainConfig)
from repro.core import ssl as ssl_mod
from repro.data import iid_partition, synthetic_images
from repro.federated.driver import run_fedssl

CFG = ModelConfig("t-vit", "dense", 4, 48, 4, 4, 96, 0, causal=False,
                  compute_dtype="float32", act="gelu")
SSLC = SSLConfig(proj_hidden=96, pred_hidden=96, proj_dim=24)
TC = TrainConfig(batch_size=32, base_lr=1.5e-4)


def _run(schedule, rounds=4, clients=2, samples=128, local_epochs=1,
         **fl_kw):
    key = jax.random.PRNGKey(0)
    imgs, _ = synthetic_images(key, samples, 10, 32)
    idx = [jnp.asarray(i) for i in iid_partition(samples, clients)]
    fl = FLConfig(num_clients=clients, rounds=rounds,
                  local_epochs=local_epochs, schedule=schedule,
                  server_epochs=1, **fl_kw)
    return run_fedssl(CFG, SSLC, fl, TC, images=imgs, client_indices=idx,
                      aux_images=imgs[:32], key=key)


@pytest.mark.slow
@pytest.mark.parametrize("schedule", ["e2e", "layerwise", "lw_fedssl",
                                      "progressive", "fll_dd"])
def test_schedule_runs_and_loss_finite(schedule):
    state, hist = _run(schedule, rounds=4,
                       depth_dropout=0.5 if schedule == "fll_dd" else 0.0)
    assert len(hist.loss) == 4
    assert all(jnp.isfinite(jnp.float32(l)) for l in hist.loss)
    # staged schedules walk the stages
    if schedule != "e2e":
        assert hist.round_stage == [1, 2, 3, 4]


@pytest.mark.slow
def test_lw_fedssl_comm_signature():
    """Paper Fig. 5c/5d: LW-FedSSL download grows with stage, upload flat;
    e2e both constant and larger."""
    _, lw = _run("lw_fedssl", rounds=4)
    assert lw.download_bytes[-1] > lw.download_bytes[0]
    assert len(set(lw.upload_bytes[1:])) == 1
    _, e2e = _run("e2e", rounds=4)
    assert len(set(e2e.download_bytes)) == 1
    assert e2e.upload_bytes[0] > lw.upload_bytes[0]
    assert e2e.total_comm > lw.total_comm


@pytest.mark.slow
def test_layerwise_cheaper_than_e2e_comm():
    _, lw = _run("layerwise", rounds=4)
    _, prog = _run("progressive", rounds=4)
    _, e2e = _run("e2e", rounds=4)
    assert lw.total_comm < prog.total_comm < e2e.total_comm


@pytest.mark.slow
def test_loss_decreases_over_rounds():
    # window-averaged: single-round SSL losses are augmentation-noisy
    state, hist = _run("e2e", rounds=6, samples=160, local_epochs=2)
    assert sum(hist.loss[-2:]) / 2 < sum(hist.loss[:2]) / 2


@pytest.mark.slow
def test_client_sampling_runs():
    state, hist = _run("lw_fedssl", rounds=4, clients=4,
                       clients_per_round=2)
    assert len(hist.loss) == 4
