"""Federated runtime invariants: FedAvg, comm accounting, partitioners."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs.base import FLConfig, ModelConfig
from repro.core import schedule as sched
from repro.data.partition import dirichlet_partition, iid_partition
from repro.federated import aggregate, comm
from repro.models import lm as lm_mod


@given(n=st.integers(2, 6), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_fedavg_weighted_mean(n, seed):
    key = jax.random.PRNGKey(seed)
    trees = []
    for i in range(n):
        key, k = jax.random.split(key)
        trees.append({"a": jax.random.normal(k, (3, 4)),
                      "b": {"c": jax.random.normal(k, (2,))}})
    counts = np.arange(1, n + 1)
    w = aggregate.client_weights(counts)
    out = aggregate.fedavg(trees, w)
    want = sum(float(w[i]) * np.asarray(trees[i]["a"]) for i in range(n))
    assert np.allclose(np.asarray(out["a"]), want, atol=1e-5)


def test_fedavg_identity():
    t = {"x": jnp.ones((4,))}
    out = aggregate.fedavg([t, t, t], aggregate.client_weights([1, 1, 1]))
    assert jnp.allclose(out["x"], 1.0)


def test_comm_accounting_matches_schedule(rng):
    """LW-FedSSL: download grows with stage, upload constant (paper Fig 5)."""
    cfg = ModelConfig("t", "dense", 6, 32, 2, 2, 64, 50,
                      compute_dtype="float32")
    params = lm_mod.init_lm(rng, cfg)
    fl = FLConfig(rounds=12, schedule="lw_fedssl")
    plans = sched.build_schedule(fl, 6)
    downs, ups = [], []
    for p in plans:
        cb = comm.round_comm_bytes(params, p, include_heads=False)
        downs.append(cb["download"])
        ups.append(cb["upload"])
    stage_of = [p.stage for p in plans]
    # downloads non-decreasing with stage; strictly more at later stage
    for i in range(1, len(plans)):
        if stage_of[i] > stage_of[i - 1]:
            assert downs[i] > downs[i - 1]
    # upload = one block, constant across stages
    assert len(set(ups[2:])) == 1          # stage>=2: exactly one block
    # e2e exchanges the whole encoder every round
    e2e = sched.build_schedule(FLConfig(rounds=2, schedule="e2e"), 6)[0]
    cb = comm.round_comm_bytes(params, e2e, include_heads=False)
    assert cb["download"] >= downs[-1]
    assert cb["upload"] > ups[-1]


def test_comm_progressive_upload_grows(rng):
    cfg = ModelConfig("t", "dense", 4, 32, 2, 2, 64, 50,
                      compute_dtype="float32")
    params = lm_mod.init_lm(rng, cfg)
    plans = sched.build_schedule(
        FLConfig(rounds=8, schedule="progressive"), 4)
    ups = [comm.round_comm_bytes(params, p)["upload"] for p in plans]
    stages = [p.stage for p in plans]
    for i in range(1, len(plans)):
        if stages[i] > stages[i - 1]:
            assert ups[i] > ups[i - 1]


def test_tree_bytes(rng):
    t = {"a": jnp.zeros((10, 10), jnp.float32),
         "b": jnp.zeros((5,), jnp.int32)}
    assert comm.tree_bytes(t) == 400 + 20


@given(n_clients=st.integers(2, 10), n=st.integers(100, 500),
       seed=st.integers(0, 20))
@settings(max_examples=20, deadline=None)
def test_iid_partition_covers_everything(n_clients, n, seed):
    parts = iid_partition(n, n_clients, seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n


@given(beta=st.sampled_from([0.1, 0.5, 5.0]), seed=st.integers(0, 10))
@settings(max_examples=15, deadline=None)
def test_dirichlet_partition_properties(beta, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, 400)
    parts = dirichlet_partition(labels, 5, beta, seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == 400 and len(np.unique(allidx)) == 400
    assert all(len(p) >= 1 for p in parts)


def test_dirichlet_lower_beta_more_skewed():
    labels = np.random.default_rng(0).integers(0, 10, 2000)

    def skew(beta):
        parts = dirichlet_partition(labels, 5, beta, seed=1)
        # mean per-client label-distribution entropy (lower = more skew)
        ents = []
        for p in parts:
            h = np.bincount(labels[p], minlength=10) / len(p)
            ents.append(-np.sum(h[h > 0] * np.log(h[h > 0])))
        return np.mean(ents)

    assert skew(0.05) < skew(100.0)


def test_upload_embed_follows_active_from(rng):
    """Upload carries the embedding side iff the client trained it
    (active_from == 0) — the rule stage_update_mask uses — instead of the
    historical ``sub_layers == stage`` check that was vacuously true."""
    cfg = ModelConfig("t", "dense", 4, 32, 2, 2, 64, 50,
                      compute_dtype="float32")
    params = lm_mod.init_lm(rng, cfg)
    embed_bytes = int(np.prod(params["embed"].shape) * 4)
    for mode, carries_embed_late in (("layerwise", False),
                                     ("progressive", True)):
        plans = sched.build_schedule(FLConfig(rounds=8, schedule=mode), 4)
        late = next(p for p in plans if p.stage == 3)
        rng_, emb = comm.plan_payloads(late)["upload"]
        assert emb == (late.active_from == 0) == carries_embed_late
        # and the byte count moves with it
        with_e = comm.partial_bytes(params, rng_, include_embed=True,
                                    include_heads=False)
        without = comm.partial_bytes(params, rng_, include_embed=False,
                                     include_heads=False)
        assert with_e - without >= embed_bytes


def test_comm_ratios_match_paper_tables():
    """Regression-pin the analytic layerwise-vs-e2e byte ratios against
    the paper's Table 1 / Table 3 communication columns (full-size ViT-T,
    180 rounds): comm multipliers vs FedMoCo of 0.08 (FedMoCo-LW / FLL+DD),
    0.31 (LW-FedSSL), 0.54 (Prog-FedSSL), and the Table 1 ~12x reduction."""
    from benchmarks import resources

    base = resources.schedule_costs("e2e")["comm_total"]
    paper = {"layerwise": 0.08, "lw_fedssl": 0.31, "progressive": 0.54,
             "fll_dd": 0.08}
    for schedule, want in paper.items():
        got = resources.schedule_costs(schedule)["comm_total"] / base
        assert abs(got - want) <= 0.06, (schedule, got, want)
    lw_reduction = base / resources.schedule_costs("layerwise")["comm_total"]
    assert 10.0 <= lw_reduction <= 14.0     # paper Table 1: 12x


def test_client_sampling_subset(rng):
    from repro.federated.server import sample_clients
    sel = sample_clients(rng, 45, 5)
    assert len(sel) == 5 and len(set(sel)) == 5
    assert all(0 <= i < 45 for i in sel)
    assert sample_clients(rng, 10, 0) == list(range(10))
