"""Wire transport invariants: payload pack/unpack round-trips across the
vit / xlstm / zamba stacked-key families, codec error bounds, error-feedback
residual conservation, measured-vs-analytic byte parity, and fp32
bit-identity of the transport-routed driver against the legacy pytree path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import (FLConfig, ModelConfig, SSLConfig, SSMConfig,
                                TrainConfig, XLSTMConfig)
from repro.core import schedule as sched
from repro.core import ssl as ssl_mod
from repro.federated import aggregate, comm, server
from repro.federated import client as client_mod
from repro.federated.leaves import path_keys
from repro.federated.transport import (Transport, build_payload_spec,
                                       make_codec, pack_stage_payload,
                                       unpack_stage_payload)
from repro.models import lm as lm_mod
from repro.models import vit as vit_mod
from repro.optim import make_optimizer

FAMILIES = ("vit", "xlstm", "zamba")


def family_tree(family, seed=0):
    """A small params tree of the given stacked-key family + its stage count."""
    key = jax.random.PRNGKey(seed)
    if family == "vit":
        cfg = ModelConfig("t-vit", "dense", 4, 32, 2, 2, 64, 0, causal=False,
                          compute_dtype="float32", act="gelu")
        return vit_mod.init_vit(key, cfg), 4
    if family == "xlstm":
        cfg = ModelConfig("t-xlstm", "ssm", 4, 32, 2, 2, 64, 64,
                          compute_dtype="float32",
                          xlstm=XLSTMConfig(slstm_every=2))
        return lm_mod.init_lm(key, cfg), lm_mod.num_stages(cfg)
    cfg = ModelConfig("t-zamba", "hybrid", 4, 32, 2, 2, 64, 64,
                      compute_dtype="float32", attn_every=2,
                      ssm=SSMConfig(state_dim=16, head_dim=32, chunk_size=32))
    return lm_mod.init_lm(key, cfg), lm_mod.num_stages(cfg)


def kinds_of(spec):
    return {s.kind for s in spec.slots}


# ---------------------------------------------------------------------------
# pack / unpack structure
# ---------------------------------------------------------------------------
@given(fam=st.sampled_from(FAMILIES), lo=st.integers(0, 1),
       seed=st.integers(0, 5))
@settings(max_examples=12, deadline=None)
def test_pack_unpack_roundtrip_exact(fam, lo, seed):
    """fp32 pack -> unpack restores the sliced rows bit-exactly and leaves
    everything outside the payload at the base tree's values."""
    tree, S = family_tree(fam, seed)
    hi = min(S, lo + 1)
    spec = build_payload_spec(tree, (lo, hi), include_embed=(lo == 0),
                              include_heads=True)
    assert spec.total > 0 and "stacked" in kinds_of(spec)
    flat = pack_stage_payload(tree, spec)
    assert flat.shape == (spec.total,) and flat.dtype == jnp.float32

    base = jax.tree.map(jnp.zeros_like, tree)
    rebuilt = unpack_stage_payload(base, flat, spec)
    flat2 = pack_stage_payload(rebuilt, spec)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(flat2))
    # a leaf fully outside the payload keeps the base (zero) values
    in_spec = {s.path for s in spec.slots}
    outside = [(p, a) for p, a in
               jax.tree_util.tree_flatten_with_path(rebuilt)[0]
               if path_keys(p) not in in_spec]
    if lo > 0:
        assert outside, "staged payloads must exclude the embedding side"
    for _, a in outside:
        assert not np.any(np.asarray(a))


def test_spec_membership_follows_flags():
    tree, S = family_tree("vit")
    full = build_payload_spec(tree, (0, S), include_embed=True,
                              include_heads=True)
    assert kinds_of(full) >= {"stacked", "embed", "extra"}
    noemb = build_payload_spec(tree, (1, 2), include_embed=False,
                               include_heads=True)
    assert "embed" not in kinds_of(noemb)
    # extra leaves (final_ln) travel in every payload
    assert "extra" in kinds_of(noemb)
    # zamba's shared attention block is an extra leaf set
    ztree, zS = family_tree("zamba")
    zspec = build_payload_spec(ztree, (zS - 1, zS), include_embed=False,
                               include_heads=True)
    assert any(s.path[0] == "shared_attn" for s in zspec.slots)


# ---------------------------------------------------------------------------
# codec round-trips
# ---------------------------------------------------------------------------
def _payload(fam, seed, lo=0):
    tree, S = family_tree(fam, seed)
    spec = build_payload_spec(tree, (lo, S), include_embed=(lo == 0),
                              include_heads=True)
    return pack_stage_payload(tree, spec), spec


@given(fam=st.sampled_from(FAMILIES), seed=st.integers(0, 10))
@settings(max_examples=9, deadline=None)
def test_fp32_codec_is_identity(fam, seed):
    flat, spec = _payload(fam, seed)
    codec = make_codec("fp32")
    out = codec.decode(codec.encode(flat, spec), spec)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(out))


@given(fam=st.sampled_from(FAMILIES), name=st.sampled_from(["fp16", "bf16"]))
@settings(max_examples=6, deadline=None)
def test_cast_codec_exact_on_representable(fam, name):
    """fp16/bf16 round-trip is exact for values already representable in
    the wire dtype."""
    flat, spec = _payload(fam, 0)
    dt = jnp.float16 if name == "fp16" else jnp.bfloat16
    rep = flat.astype(dt).astype(jnp.float32)
    codec = make_codec(name)
    out = codec.decode(codec.encode(rep, spec), spec)
    np.testing.assert_array_equal(np.asarray(rep), np.asarray(out))


@given(fam=st.sampled_from(FAMILIES), seed=st.integers(0, 10))
@settings(max_examples=9, deadline=None)
def test_int8_codec_bounded_error(fam, seed):
    """Per-channel int8: |x - dq(q(x))| <= scale/2 <= amax/253 per channel."""
    flat, spec = _payload(fam, seed)
    codec = make_codec("int8")
    out = np.asarray(codec.decode(codec.encode(flat, spec), spec))
    x = np.asarray(flat)
    err = np.abs(out - x)
    # global bound: half an int8 step of the largest channel scale
    assert err.max() <= np.abs(x).max() / 127.0 * 0.5 + 1e-7
    rel = err.max() / max(np.abs(x).max(), 1e-12)
    assert rel < 0.005


@given(fam=st.sampled_from(FAMILIES), frac=st.sampled_from([0.05, 0.2, 1.0]))
@settings(max_examples=9, deadline=None)
def test_topk_error_feedback_conservation(fam, frac):
    """decoded + new_residual == payload + old_residual, exactly: the
    dropped mass is carried, never lost."""
    flat, spec = _payload(fam, 3)
    codec = make_codec(f"topk:{frac}")
    old_res = jnp.asarray(
        np.random.default_rng(0).normal(size=flat.shape).astype(np.float32))
    comp = flat + old_res
    wire = codec.encode(comp, spec)
    dec = codec.decode(wire, spec)
    new_res = comp - dec
    np.testing.assert_array_equal(np.asarray(dec + new_res),
                                  np.asarray(comp))
    k = codec.k_for(spec)
    assert wire["idx"].shape == (k,) and wire["val"].shape == (k,)
    assert int(np.count_nonzero(np.asarray(dec))) <= k
    if frac == 1.0:
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(comp))


def test_make_codec_registry():
    assert make_codec("topk:0.25").fraction == 0.25
    with pytest.raises(ValueError):
        make_codec("gzip")
    with pytest.raises(ValueError):
        make_codec("topk:0")


# ---------------------------------------------------------------------------
# measured wire bytes vs analytic accounting
# ---------------------------------------------------------------------------
def _ssl_online(seed=0):
    cfg = ModelConfig("t-vit", "dense", 4, 32, 2, 2, 64, 0, causal=False,
                      compute_dtype="float32", act="gelu")
    sslc = SSLConfig(proj_hidden=32, pred_hidden=32, proj_dim=16)
    enc = ssl_mod.make_vit_encoder(cfg)
    state = ssl_mod.ssl_init(jax.random.PRNGKey(seed), enc, sslc)
    return state["online"]


@pytest.mark.parametrize("schedule", sched.SCHEDULES)
@pytest.mark.parametrize("include_heads", [True, False])
def test_fp32_wire_bytes_match_analytic(schedule, include_heads):
    """Identity codec: measured wire bytes == comm.round_comm_bytes for
    every round of every schedule, both directions."""
    online = _ssl_online()
    t = Transport("fp32", include_heads=include_heads)
    plans = sched.build_schedule(FLConfig(rounds=8, schedule=schedule), 4)
    for plan in plans:
        cb = comm.round_comm_bytes(online, plan,
                                   include_heads=include_heads)
        specs = t.plan_specs(online, plan)
        assert t.wire_bytes(specs["download"]) == cb["download"], plan
        assert t.wire_bytes(specs["upload"]) == cb["upload"], plan


@pytest.mark.parametrize("codec,min_ratio", [
    ("fp16", 1.9), ("bf16", 1.9), ("int8", 3.5), ("topk:0.1", 4.5)])
def test_codec_measured_compression(codec, min_ratio):
    online = _ssl_online()
    t = Transport(codec)
    plan = sched.build_schedule(FLConfig(rounds=4, schedule="e2e"), 4)[0]
    spec = t.plan_specs(online, plan)["upload"]
    ratio = spec.payload_bytes / t.wire_bytes(spec)
    assert ratio >= min_ratio


# ---------------------------------------------------------------------------
# transport-level aggregation semantics
# ---------------------------------------------------------------------------
def test_aggregate_uploads_fp32_equals_fedavg():
    """With the identity codec, transport aggregation == full-tree FedAvg
    when clients only changed payload leaves (the layer-wise contract)."""
    online = _ssl_online()
    plan = sched.build_schedule(FLConfig(rounds=4, schedule="e2e"), 4)[0]
    t = Transport("fp32")
    outs = []
    for i in range(3):
        d = jax.random.PRNGKey(100 + i)
        outs.append(jax.tree.map(
            lambda a: a + 0.01 * jax.random.normal(
                jax.random.fold_in(d, hash(str(a.shape)) % 97), a.shape),
            online))
    w = aggregate.client_weights([1, 1, 2])
    got, stats = t.aggregate_uploads(online, outs, [0, 1, 2], plan, w)
    want = aggregate.fedavg(outs, w)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert stats["wire_bytes"] == stats["payload_bytes"]


def test_topk_broadcast_mirror():
    """Delta broadcast: a dense re-sync seeds the server-side mirror, then
    sparse deltas converge the clients' view toward the server model."""
    online = _ssl_online()
    t = Transport("topk:0.1")
    plan = sched.build_schedule(FLConfig(rounds=4, schedule="e2e"), 4)[0]
    view1, s1 = t.broadcast(online, plan)
    for a, b in zip(jax.tree.leaves(view1), jax.tree.leaves(online)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert s1["wire_bytes"] == s1["payload_bytes"]

    def maxerr(view, ref):
        return max(float(jnp.max(jnp.abs(a - b))) for a, b in
                   zip(jax.tree.leaves(view), jax.tree.leaves(ref)))

    online2 = jax.tree.map(lambda a: a * 1.01, online)
    view2, s2 = t.broadcast(online2, plan)
    assert s2["wire_bytes"] < s2["payload_bytes"] / 3   # sparse delta round
    err2 = maxerr(view2, online2)
    # keep broadcasting the same model: each sparse round ships more of
    # the remaining delta, so the client view converges (mirror EF)
    err = err2
    for _ in range(3):
        view, _ = t.broadcast(online2, plan)
        new_err = maxerr(view, online2)
        assert new_err <= err + 1e-12
        err = new_err
    assert err < err2 or err == 0.0


def test_residual_store_resets_on_spec_change():
    online = _ssl_online()
    t = Transport("topk:0.1")
    plans = sched.build_schedule(FLConfig(rounds=4, schedule="layerwise"), 4)
    s1 = t.plan_specs(online, plans[0])["upload"]
    r = t.gather_residuals([0], s1)
    assert not np.any(np.asarray(r))
    t.store_residuals([0], s1, jnp.ones((1, s1.total)))
    assert np.all(np.asarray(t.gather_residuals([0], s1)) == 1.0)
    # next stage => different payload layout => residual resets to zero
    s2 = t.plan_specs(online, plans[1])["upload"]
    assert s2.sig != s1.sig
    assert not np.any(np.asarray(t.gather_residuals([0], s2)))


# ---------------------------------------------------------------------------
# pallas wire engine vs the XLA reference
# ---------------------------------------------------------------------------
def _engine_tol(codec: str) -> float:
    """fp32/fp16/bf16 ride the fused pack path and exact casts — bit
    parity. int8/topk involve a division whose fusion differs between
    eager and jit'd XLA by 1 ulp, so the decoded trees get float
    tolerance."""
    return 0.0 if codec in ("fp32", "fp16", "bf16") else 1e-6


def _assert_trees_match(a, b, atol, what):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), what
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if atol == 0.0:
            np.testing.assert_array_equal(x, y, err_msg=what)
        else:
            d = np.abs(x.astype(np.float64) - y.astype(np.float64)).max()
            assert d <= atol, (what, float(d))


def test_transport_rejects_unknown_kernels():
    with pytest.raises(ValueError):
        Transport("fp32", kernels="cuda")


@pytest.mark.parametrize("codec", ["fp32", "fp16", "int8", "topk:0.2"])
@given(fam=st.sampled_from(FAMILIES))
@settings(max_examples=3, deadline=None)
def test_pallas_engine_matches_xla(codec, fam):
    """Both wire engines produce the same broadcasts, aggregated uploads
    and error-feedback residuals, for every schedule's mid-round payload
    on every model family."""
    params, stages = family_tree(fam, seed=3)
    pert = jax.tree.map(
        lambda a: a + 0.02 * jax.random.normal(
            jax.random.PRNGKey(7), a.shape, a.dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, params)
    atol = _engine_tol(codec)
    w = aggregate.client_weights([1, 2])
    for schedule in sched.SCHEDULES:
        plans = sched.build_schedule(FLConfig(rounds=4, schedule=schedule),
                                     stages)
        plan = plans[len(plans) // 2]
        tx = Transport(codec, kernels="xla")
        tp = Transport(codec, kernels="pallas")
        # two broadcasts: delta codecs do dense sync then a sparse delta
        for r, src in enumerate((params, pert)):
            vx, sx = tx.broadcast(src, plan)
            vp, sp = tp.broadcast(src, plan)
            assert sx == sp, (schedule, codec)
            _assert_trees_match(vx, vp, atol,
                                f"bcast {fam}/{schedule}/{codec} r{r}")
        # two aggregation rounds so error feedback carries residuals
        for r in range(2):
            ax, _ = tx.aggregate_uploads(params, [pert, params],
                                         ["a", "b"], plan, w)
            ap, _ = tp.aggregate_uploads(params, [pert, params],
                                         ["a", "b"], plan, w)
            _assert_trees_match(ax, ap, atol,
                                f"agg {fam}/{schedule}/{codec} r{r}")
        if tx.codec.error_feedback:
            spec = tx.plan_specs(params, plan)["upload"]
            _assert_trees_match(tx.gather_residuals(["a", "b"], spec),
                                tp.gather_residuals(["a", "b"], spec),
                                1e-7, f"resid {fam}/{schedule}/{codec}")


# ---------------------------------------------------------------------------
# fp32 driver bit-parity against the legacy (pytree hand-off) FL loop
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_fp32_driver_bit_identical_to_legacy_loop():
    """run_fedssl with the identity codec must reproduce the pre-transport
    driver bit-for-bit: same RNG chain, local training from the server
    pytree, full-tree FedAvg."""
    from repro.data import iid_partition, synthetic_images
    from repro.federated.driver import run_fedssl

    cfg = ModelConfig("t-vit", "dense", 2, 32, 2, 2, 64, 0, causal=False,
                      compute_dtype="float32", act="gelu")
    sslc = SSLConfig(proj_hidden=32, pred_hidden=32, proj_dim=16)
    tc = TrainConfig(batch_size=16, base_lr=1.5e-4)
    fl = FLConfig(num_clients=2, rounds=2, local_epochs=1,
                  schedule="layerwise")
    key = jax.random.PRNGKey(0)
    imgs, _ = synthetic_images(key, 64, 10, 32)
    idx = [jnp.asarray(i) for i in iid_partition(64, 2)]

    state, hist = run_fedssl(cfg, sslc, fl, tc, images=imgs,
                             client_indices=idx, key=key, codec="fp32")
    assert hist.wire_download_bytes == hist.download_bytes
    assert hist.wire_upload_bytes == hist.upload_bytes
    assert hist.compression_ratio == 1.0

    # legacy loop: the seed driver's exact control flow, no transport
    from repro.optim.schedules import learning_rate, scaled_base_lr
    encoder = ssl_mod.make_vit_encoder(cfg)
    k = jax.random.PRNGKey(0)
    k_init, k = jax.random.split(k)
    lstate = ssl_mod.ssl_init(k_init, encoder, sslc)
    opt = make_optimizer(tc)
    plans = sched.build_schedule(fl, encoder.num_stages)
    base_lr = scaled_base_lr(tc.base_lr, tc.batch_size)
    counts = [len(i) for i in idx]
    stage_start = {}
    for p in plans:
        stage_start.setdefault(p.stage, p.round_idx)
    stage_lengths = {s: sum(1 for p in plans if p.stage == s)
                     for s in set(p.stage for p in plans)}
    for plan in plans:
        if plan.new_stage:
            lstate = server.begin_stage(lstate, plan.stage,
                                        weight_transfer=fl.weight_transfer)
        lr = float(learning_rate(
            plan.round_idx, fl.rounds, base_lr, tc.lr_schedule,
            stage_step=plan.round_idx - stage_start[plan.stage],
            stage_total=stage_lengths[plan.stage],
            warmup_steps=tc.warmup_steps))
        k, ks = jax.random.split(k)
        participants = server.sample_clients(ks, fl.num_clients,
                                             fl.clients_per_round)
        step_fn = client_mod.make_local_step(
            encoder, sslc, opt, sub_layers=plan.sub_layers,
            active_from=plan.active_from, align=plan.align,
            depth_dropout=plan.depth_dropout)
        outs = []
        for i in participants:
            k, kc = jax.random.split(k)
            online_i, _ = client_mod.local_train(
                lstate, imgs[idx[i]], step_fn, opt,
                epochs=fl.local_epochs, batch_size=tc.batch_size, key=kc,
                lr=lr, global_enc=None)
            outs.append(online_i)
        w = aggregate.client_weights([counts[i] for i in participants])
        lstate = {**lstate, "online": aggregate.fedavg(outs, w)}

    for a, b in zip(jax.tree.leaves(state["online"]),
                    jax.tree.leaves(lstate["online"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# lossy codecs still train (tier-1 integration config, reduced rounds)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_lossy_codecs_train_close_to_fp32():
    from repro.data import iid_partition, synthetic_images
    from repro.federated.driver import run_fedssl

    cfg = ModelConfig("t-vit", "dense", 2, 32, 2, 2, 64, 0, causal=False,
                      compute_dtype="float32", act="gelu")
    sslc = SSLConfig(proj_hidden=32, pred_hidden=32, proj_dim=16)
    tc = TrainConfig(batch_size=16, base_lr=1.5e-4)
    key = jax.random.PRNGKey(0)
    imgs, _ = synthetic_images(key, 64, 10, 32)
    idx = [jnp.asarray(i) for i in iid_partition(64, 2)]

    def final_loss(codec):
        fl = FLConfig(num_clients=2, rounds=2, local_epochs=1,
                      schedule="e2e")
        _, hist = run_fedssl(cfg, sslc, fl, tc, images=imgs,
                             client_indices=idx, key=key, codec=codec)
        return hist

    ref = final_loss("fp32")
    for codec in ("fp16", "int8", "topk:0.1"):
        h = final_loss(codec)
        assert np.isfinite(h.loss[-1])
        assert abs(h.loss[-1] - ref.loss[-1]) <= 0.1 * abs(ref.loss[-1])
        assert h.compression_ratio >= 1.9
