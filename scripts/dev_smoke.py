import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig, SSMConfig, XLSTMConfig
from repro.models import lm, encdec, vit

key = jax.random.PRNGKey(0)


def check_lm(cfg, S=64, Bsz=2):
    p = lm.init_lm(key, cfg)
    tok = jax.random.randint(key, (Bsz, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    loss, met = jax.jit(lambda p, b: lm.lm_loss(p, b, cfg))(p, batch)
    assert jnp.isfinite(loss), (cfg.arch_id, loss)
    # staged loss (LW stage 2 of reduced model)
    loss2, _ = jax.jit(lambda p, b: lm.lm_loss(p, b, cfg, sub_layers=1, active_from=0))(p, batch)
    assert jnp.isfinite(loss2)
    # decode
    caches = lm.init_caches(cfg, Bsz, 32)
    logits, caches = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, jnp.int32(0), cfg))(p, caches, tok[:, :1])
    assert logits.shape == (Bsz, 1, cfg.vocab_size) and jnp.isfinite(logits).all()
    # prefill
    lg, _ = jax.jit(lambda p, t: lm.prefill(p, t, cfg))(p, tok)
    assert jnp.isfinite(lg).all()
    print("OK", cfg.arch_id, float(loss))


dense = ModelConfig("t-dense", "dense", 2, 128, 4, 2, 256, 128, compute_dtype="float32")
check_lm(dense)

moe = ModelConfig("t-moe", "moe", 2, 128, 4, 2, 0, 128, compute_dtype="float32",
                  moe=MoEConfig(4, 2, 1, 128))
check_lm(moe)

mla = ModelConfig("t-mla", "moe", 2, 128, 4, 4, 0, 128, compute_dtype="float32",
                  moe=MoEConfig(4, 2, 1, 128),
                  mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16))
check_lm(mla)

ssm = ModelConfig("t-mamba", "ssm", 2, 128, 4, 4, 0, 128, compute_dtype="float32",
                  ssm=SSMConfig(state_dim=16, head_dim=32, chunk_size=16))
check_lm(ssm)

xl = ModelConfig("t-xlstm", "ssm", 4, 128, 4, 4, 0, 128, compute_dtype="float32",
                 xlstm=XLSTMConfig(slstm_every=2))
check_lm(xl)

zam = ModelConfig("t-zamba", "hybrid", 4, 128, 4, 2, 256, 128, compute_dtype="float32",
                  attn_every=2, ssm=SSMConfig(state_dim=16, head_dim=32, chunk_size=16))
check_lm(zam)

wind = ModelConfig("t-window", "dense", 2, 128, 4, 2, 256, 128, compute_dtype="float32", window=16)
check_lm(wind)

# enc-dec
ed = ModelConfig("t-encdec", "audio", 2, 128, 4, 4, 256, 128, compute_dtype="float32",
                 dec_layers=2, cross_attention=True, frontend_embed_len=8)
p = encdec.init_encdec(key, ed)
frames = jax.random.normal(key, (2, 8, 128))
tok = jax.random.randint(key, (2, 16), 0, ed.vocab_size)
loss, _ = jax.jit(lambda p, f, t: encdec.encdec_loss(p, {"frontend": f, "tokens": t, "labels": t}, ed))(p, frames, tok)
assert jnp.isfinite(loss)
caches = encdec.init_dec_caches(ed, 2, 16)
lg, caches = jax.jit(lambda p, c, t, m: encdec.decode_step(p, c, t, jnp.int32(0), m, ed))(p, caches, tok[:, :1], frames)
assert jnp.isfinite(lg).all()
print("OK encdec", float(loss))

# vit
vt = ModelConfig("t-vit", "dense", 2, 128, 4, 4, 256, 0, causal=False, compute_dtype="float32", act="gelu")
pv = vit.init_vit(key, vt)
imgs = jax.random.normal(key, (2, 32, 32, 3))
rep = jax.jit(lambda p, x: vit.vit_forward(p, x, vt))(pv, imgs)
assert rep.shape == (2, 128) and jnp.isfinite(rep).all()
rep2 = jax.jit(lambda p, x: vit.vit_forward(p, x, vt, sub_layers=1, active_from=0))(pv, imgs)
assert jnp.isfinite(rep2).all()
print("OK vit")
print("ALL MODELS OK")
