"""§Perf A/B harness: lower one (arch, shape, mode) with named knob
settings and print the roofline deltas.

Usage:
  PYTHONPATH=src python scripts/perf_iter.py --arch internlm2-1.8b \
      --shape train_4k --knob xent_gold=take --knob xent_gold=mask
Each --knob value is lowered in sequence; results print side by side and
append to results/perf_iters.json.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")

import argparse          # noqa: E402
import json              # noqa: E402
import pathlib           # noqa: E402
import sys               # noqa: E402

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"


def apply_knob(knob: str):
    """knob 'name=value' -> mutate the corresponding global."""
    name, value = knob.split("=", 1)
    if name == "xent_gold":
        from repro.models import lm
        lm.XENT_GOLD_MODE = value
    elif name == "act_dtype":
        from repro.models import lm
        lm.ACT_DTYPE = value
    elif name == "loss_chunk":
        from repro.models import lm
        lm.LOSS_CHUNK = int(value)
    elif name == "kv_repl":              # wk/wv output replication on/off
        from repro.sharding import rules
        if value == "on":
            rules.PARAM_RULES["wk"] = ("fsdp", None)
            rules.PARAM_RULES["wv"] = ("fsdp", None)
        else:
            rules.PARAM_RULES["wk"] = ("fsdp", "tp")
            rules.PARAM_RULES["wv"] = ("fsdp", "tp")
    elif name == "embed_fsdp_only":      # embedding: no vocab TP sharding
        from repro.sharding import rules
        if value == "on":
            rules.PARAM_RULES["embed"] = (None, "fsdp")
            rules.PARAM_RULES["lm_head"] = ("fsdp", None)
        else:
            rules.PARAM_RULES["embed"] = ("tp", "fsdp")
            rules.PARAM_RULES["lm_head"] = ("fsdp", "tp")
    elif name == "seq_shard":            # sequence-parallel residual stream
        from repro.models import lm
        lm.SEQ_SHARD = value == "on"
    elif name == "remat_policy":         # None | dots
        from repro.models import lm
        lm.REMAT_POLICY = None if value == "none" else value
    else:
        raise ValueError(name)
    return knob


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mode", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--knob", action="append", default=[],
                    help="name=value; lowered once per knob setting")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    from repro.launch import dryrun
    rows = []
    for knob in (args.knob or ["baseline=none"]):
        if knob != "baseline=none":
            apply_knob(knob)
        row = dryrun.run_one(args.arch, args.shape,
                             multi_pod=args.multi_pod, mode=args.mode)
        row["knob"] = knob
        row["tag"] = args.tag
        rows.append(row)
    out = RESULTS / "perf_iters.json"
    prev = json.loads(out.read_text()) if out.exists() else []
    out.write_text(json.dumps(prev + rows, indent=1))
    if len(rows) > 1:
        b = rows[0]
        for r in rows[1:]:
            print(f"\n{r['knob']} vs {b['knob']}:")
            for k in ("compute_s", "memory_s", "collective_s"):
                d = (r[k] - b[k]) / max(b[k], 1e-12) * 100
                print(f"  {k:13s} {b[k]*1e3:10.2f} -> {r[k]*1e3:10.2f} ms "
                      f"({d:+.1f}%)")


if __name__ == "__main__":
    main()
