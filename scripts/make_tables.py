"""Format results/dryrun_*.json into the EXPERIMENTS.md roofline tables."""
import json
import pathlib
import sys

RES = pathlib.Path(__file__).resolve().parents[1] / "results"

ORDER = ["zamba2-2.7b", "internlm2-1.8b", "xlstm-125m", "internvl2-1b",
         "seamless-m4t-medium", "mistral-large-123b",
         "llama4-maverick-400b-a17b", "internlm2-20b", "starcoder2-15b",
         "deepseek-v2-236b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def table(path, title):
    rows = {(r["arch"], r["shape"]): r
            for r in json.loads(path.read_text())}
    out = [f"### {title}", "",
           "| arch | shape | compute | memory | collective | dominant | "
           "useful | peak HBM/dev |",
           "|---|---|---:|---:|---:|---|---:|---:|"]
    for a in ORDER:
        for s in SHAPES:
            r = rows.get((a, s))
            if not r:
                out.append(f"| {a} | {s} | - | - | - | MISSING | - | - |")
                continue
            note = " *" if r.get("method") == "depth-extrapolated" else ""
            peak = max(r["mem_per_device"]["peak_bytes"],
                       r["mem_per_device"]["argument_bytes"])
            out.append(
                f"| {a} | {s} | {r['compute_s']*1e3:.1f} ms | "
                f"{r['memory_s']*1e3:.0f} ms | "
                f"{r['collective_s']*1e3:.0f} ms | {r['dominant']}{note} | "
                f"{min(r['useful_ratio'], 9.99)*100:.0f}% | "
                f"{peak/2**30:.2f} GiB |")
    out.append("")
    out.append("(* = depth-extrapolated, see §Dry-run methodology)")
    return "\n".join(out)


if __name__ == "__main__":
    for name, title in ((
            "dryrun_16x16.json",
            "Single-pod 16x16 (roofline terms, per device)"), (
            "dryrun_2x16x16.json",
            "Multi-pod 2x16x16 (coherence pass — rolled scans, "
            "cost terms not roofline-grade)")):
        p = RES / name
        if p.exists():
            print(table(p, title))
            print()
