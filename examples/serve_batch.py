"""Batched serving example: prefill + decode across architecture families.

Serves reduced variants of a dense (GQA), an SSM (Mamba2 hybrid) and an
MLA+MoE architecture, demonstrating the shared serving path (KV caches,
ring buffers, recurrent states, latent caches) the decode dry-run shapes
lower at full scale.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import serve

for arch in ("internlm2-1.8b", "zamba2-2.7b", "deepseek-v2-236b",
             "seamless-m4t-medium"):
    serve(arch, batch=2, prompt_len=16, gen=8)
