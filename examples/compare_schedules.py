"""Compare the five training schedules (paper Tables 1/3, Fig. 7).

Runs FedMoCo (e2e), FedMoCo-LW, LW-FedSSL, Prog-FedSSL and FLL+DD at
reduced scale with identical data/seeds and reports: final SSL loss,
linear-eval accuracy, and per-client communication — the qualitative
reproduction of the paper's central comparison.

Run:  PYTHONPATH=src python examples/compare_schedules.py [--rounds 8]
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig, SSLConfig, TrainConfig, load_arch, reduced
from repro.core import ssl as ssl_mod
from repro.data import iid_partition, synthetic_images
from repro.federated import eval as fl_eval
from repro.federated.driver import run_fedssl

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=8)
ap.add_argument("--clients", type=int, default=4)
ap.add_argument("--samples", type=int, default=768)
args = ap.parse_args()

cfg = reduced(load_arch("vit-tiny"), num_layers=4, d_model=64,
              num_heads=4, num_kv_heads=4, d_ff=128)
ssl_cfg = SSLConfig(proj_hidden=128, pred_hidden=128, proj_dim=32)
tc = TrainConfig(batch_size=32, base_lr=1.5e-4)
key = jax.random.PRNGKey(0)
images, labels = synthetic_images(key, args.samples, 10)
idx = [jnp.asarray(i) for i in iid_partition(args.samples, args.clients)]
aux = images[: args.samples // 8]
encoder = ssl_mod.make_vit_encoder(cfg)

print(f"{'schedule':14s} {'loss':>8s} {'acc%':>7s} {'comm MB':>9s}")
for schedule in ("e2e", "layerwise", "lw_fedssl", "progressive", "fll_dd"):
    fl = FLConfig(num_clients=args.clients, rounds=args.rounds,
                  local_epochs=1, schedule=schedule, server_epochs=1,
                  depth_dropout=0.5 if schedule == "fll_dd" else 0.0)
    state, hist = run_fedssl(cfg, ssl_cfg, fl, tc, images=images,
                             client_indices=idx, aux_images=aux,
                             key=jax.random.PRNGKey(1))
    n = min(256, args.samples // 2)
    acc = fl_eval.linear_eval(encoder, state["online"]["enc"],
                              images[:n], labels[:n], images[n:2 * n],
                              labels[n:2 * n], num_classes=10, epochs=4,
                              batch_size=64)
    print(f"{schedule:14s} {hist.loss[-1]:8.3f} {acc * 100:7.1f} "
          f"{hist.total_comm / 1e6:9.2f}")
