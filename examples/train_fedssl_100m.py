"""End-to-end driver: federated layer-wise SSL for a ~100M-param LM.

The assignment's end-to-end example: train a ~100M decoder (the xlstm-125m
assigned architecture at full width, shortened depth on CPU) for a few
hundred local steps with the LW-FedSSL schedule over token shards, and
show the loss trajectory + per-stage communication.

By default runs a CPU-sized slice (--steps 200). With --full-width it
builds the real 125M-parameter config (slow on CPU but bounded memory
thanks to layer-wise training — the paper's point).

Run:  PYTHONPATH=src python examples/train_fedssl_100m.py --rounds 4
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig, TrainConfig, load_arch, reduced
from repro.core import schedule as sched
from repro.core.ssl import lm_ssl_loss
from repro.data import iid_partition
from repro.data.synthetic import synthetic_tokens
from repro.federated import aggregate
from repro.federated.masks import stage_update_mask
from repro.models import lm as lm_mod
from repro.optim import make_optimizer
from repro.optim.schedules import learning_rate

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=4)
ap.add_argument("--clients", type=int, default=2)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq-len", type=int, default=256)
ap.add_argument("--steps-per-round", type=int, default=25)
ap.add_argument("--full-width", action="store_true")
args = ap.parse_args()

base = load_arch("xlstm-125m")
if args.full_width:
    cfg = dataclasses.replace(
        base, num_layers=4,
        xlstm=dataclasses.replace(base.xlstm, slstm_every=2))
else:
    cfg = reduced(base, num_layers=4, d_model=256, vocab_size=2048,
                  xlstm=dataclasses.replace(base.xlstm, slstm_every=2))
print(f"arch {cfg.arch_id}: ~{cfg.param_count() / 1e6:.1f}M params, "
      f"{lm_mod.num_stages(cfg)} layer-wise stages")

fl = FLConfig(num_clients=args.clients, rounds=args.rounds,
              schedule="lw_fedssl")
tc = TrainConfig(batch_size=args.batch, base_lr=3e-4)
S = lm_mod.num_stages(cfg)
plans = sched.build_schedule(fl, S)
opt = make_optimizer(tc)
key = jax.random.PRNGKey(0)
kd, ki, key = jax.random.split(key, 3)
n_seq = args.clients * args.batch * 8
toks, labs = synthetic_tokens(kd, n_seq, args.seq_len, cfg.vocab_size)
shards = iid_partition(n_seq, args.clients)
params = lm_mod.init_lm(ki, cfg)

step_cache = {}


def get_step(sub, act):
    if (sub, act) not in step_cache:
        @jax.jit
        def step(params, opt_state, batch, global_params, lr):
            def loss_fn(p):
                return lm_ssl_loss(p, batch, cfg, sub_layers=sub,
                                   active_from=act,
                                   global_params=global_params,
                                   align_weight=0.01)
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
            mask = stage_update_mask(params, sub, act)
            p2, o2 = opt.update(g, opt_state, params, lr, mask)
            return p2, o2, l
        step_cache[(sub, act)] = step
    return step_cache[(sub, act)]


t0 = time.time()
total_steps = 0
for plan in plans:
    if plan.new_stage:
        params = sched.transfer_model(params, cfg, plan.stage)
    lr = float(learning_rate(plan.round_idx, fl.rounds,
                             tc.base_lr, "cosine"))
    step = get_step(plan.sub_layers, plan.active_from)
    global_params = jax.tree.map(jnp.copy, params)
    outs, losses = [], []
    for ci in range(fl.num_clients):
        p_i = jax.tree.map(jnp.asarray, params)
        o_i = opt.init(p_i)
        ix = shards[ci]
        for b in range(args.steps_per_round):
            sel = ix[(b * args.batch) % (len(ix) - args.batch):][:args.batch]
            batch = {"tokens": toks[sel], "labels": labs[sel]}
            p_i, o_i, loss = step(p_i, o_i, batch, global_params,
                                  jnp.float32(lr))
            total_steps += 1
        outs.append(p_i)
        losses.append(float(loss))
    params = aggregate.fedavg(outs, aggregate.client_weights(
        [len(s) for s in shards]))
    print(f"round {plan.round_idx + 1}/{fl.rounds} stage {plan.stage} "
          f"mean client loss {sum(losses) / len(losses):.4f}")

print(f"{total_steps} local steps in {time.time() - t0:.1f}s")
