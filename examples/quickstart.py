"""Quickstart: LW-FedSSL in ~60 lines.

Trains the paper's system end-to-end at toy scale on CPU:
10 synthetic-image clients, a reduced ViT encoder, MoCo v3 SSL, the
layer-wise schedule with server-side calibration + representation
alignment, then linear evaluation.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig, SSLConfig, TrainConfig, load_arch, reduced
from repro.core import ssl as ssl_mod
from repro.data import iid_partition, synthetic_images
from repro.federated import eval as fl_eval
from repro.federated.driver import run_fedssl

# 1. model: reduced ViT (the paper uses ViT-Tiny with 12 blocks; we shrink
#    to 4 blocks so the demo runs in a couple of minutes on CPU)
cfg = reduced(load_arch("vit-tiny"), num_layers=4, d_model=64,
              num_heads=4, num_kv_heads=4, d_ff=128)
ssl_cfg = SSLConfig(method="moco_v3", proj_hidden=128, pred_hidden=128,
                    proj_dim=32, align_weight=0.01)

# 2. federated setting: 4 clients, 4 rounds = 1 round per layer-wise stage
fl = FLConfig(num_clients=4, rounds=4, local_epochs=1,
              schedule="lw_fedssl", server_epochs=1)
train_cfg = TrainConfig(batch_size=32, base_lr=1.5e-4)

# 3. data: synthetic stand-in for STL-10 (offline container)
key = jax.random.PRNGKey(0)
images, labels = synthetic_images(key, 512, num_classes=10)
client_idx = [jnp.asarray(i) for i in iid_partition(512, fl.num_clients)]
aux_images = images[:64]          # the server's auxiliary dataset D_g

# 4. run the FL process (Algorithms 1 + 2)
state, hist = run_fedssl(cfg, ssl_cfg, fl, train_cfg, images=images,
                         client_indices=client_idx, aux_images=aux_images,
                         key=key, log=print)
print(f"\ntotal communication: {hist.total_comm / 1e6:.2f} MB "
      f"(download grows with stage, upload stays one layer)")

# 5. linear evaluation on the frozen encoder
encoder = ssl_mod.make_vit_encoder(cfg)
acc = fl_eval.linear_eval(encoder, state["online"]["enc"],
                          images[:256], labels[:256],
                          images[256:], labels[256:],
                          num_classes=10, epochs=5, batch_size=64)
print(f"linear evaluation accuracy: {acc * 100:.1f}%")
