"""Pallas TPU flash attention (GQA, causal / sliding-window).

Online-softmax attention with explicit VMEM tiling:

  grid = (batch, q_heads, S // bq, T // bk)   — last axis sequential
  Q block   (bq, hd)   VMEM
  K/V block (bk, hd)   VMEM, indexed by kv_head = q_head // group
  scratch   acc (bq, hd) f32, m/l (bq, 128) f32 — persists across the kv axis

The kv axis is ``arbitrary`` (sequential) so the scratch carries the
running row-max / row-sum / accumulator; fully-masked KV blocks are skipped
with ``pl.when`` (the roofline win over XLA's dense masking for causal and
sliding-window attention). Block shapes are MXU-aligned: bq, bk multiples
of 128 (the ops wrapper pads head_dim and sequence as needed).

Validated in interpret mode against ``repro.kernels.ref.sdpa_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, causal: bool, window: int, nk: int,
                  scale: float, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk
    # block-level skip: causal => kv block after the last query; window =>
    # kv block entirely before the window of the first query
    run = True
    if causal:
        run = k_start <= q_start + bq - 1
    if window:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[...].astype(jnp.float32)                 # (bq, hd)
        k = k_ref[...].astype(jnp.float32)                 # (bk, hd)
        v = v_ref[...].astype(jnp.float32)                 # (bk, hd)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len          # exclude zero-padded kv tail
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev = m_ref[:, 0]                                # (bq,)
        l_prev = l_ref[:, 0]
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         bq: int = 128, bk: int = 128, scale: float = None,
                         kv_len: int = None, interpret: bool = False):
    """q: (B, Hq, S, hd); k, v: (B, Hkv, T, hd). Returns (B, Hq, S, hd).

    S % bq == 0, T % bk == 0, Hq % Hkv == 0 (the ops wrapper pads).
    ``scale`` must be 1/sqrt(true head dim) when hd is zero-padded.
    """
    B, Hq, S, hd = q.shape
    _, Hkv, T, _ = k.shape
    group = Hq // Hkv
    nq, nk = S // bq, T // bk
    scale = (1.0 / (hd ** 0.5)) if scale is None else scale
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window, nk=nk,
        scale=scale, kv_len=kv_len if kv_len is not None else T)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((None, None, bq, hd),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((None, None, bk, hd),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((None, None, bk, hd),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),    # acc (padded hd)
            pltpu.VMEM((bq, 128), jnp.float32),   # running max
            pltpu.VMEM((bq, 128), jnp.float32),   # running sum
        ],
        compiler_params=CompilerParams(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
