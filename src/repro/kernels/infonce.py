"""Pallas TPU fused InfoNCE loss (paper Eq. 2 hot-spot).

The SSL loss builds a (B, B) logits matrix q @ k^T / tau and immediately
reduces it to a per-row cross-entropy against the diagonal. Fusing the
matmul with the reduction means the logits tile never leaves VMEM:

  grid = (B // br, B // bc)                       — column axis sequential
  q block (br, d), k block (bc, d)
  scratch m/l/g (br, 128) f32  (running max / sum / gold logit)
  out per-row loss (br,)

Inputs are assumed L2-normalized (the wrapper normalizes). Validated in
interpret mode against ``repro.kernels.ref.info_nce_rows_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _infonce_kernel(q_ref, k_ref, o_ref, m_ref, l_ref, g_ref, *,
                    br: int, bc: int, nc: int, inv_tau: float):
    ri = pl.program_id(0)
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * inv_tau
    rows = ri * br + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 0)
    cols = ci * bc + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 1)
    diag = rows == cols
    g_ref[...] += jnp.broadcast_to(
        jnp.sum(jnp.where(diag, logits, 0.0), axis=1, keepdims=True),
        g_ref.shape)
    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[:, 0] * corr + jnp.sum(jnp.exp(logits - m_new[:, None]),
                                         axis=-1)
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ci == nc - 1)
    def _finalize():
        # loss_i = log(sum_j exp(logit_ij)) - logit_ii
        o_ref[...] = (jnp.log(jnp.maximum(l_ref[:, 0], 1e-30)) + m_ref[:, 0]
                      - g_ref[:, 0]).astype(o_ref.dtype)


def info_nce_rows(q, k, tau: float, *, br: int = 128, bc: int = 128,
                  interpret: bool = False):
    """q, k: (B, d) L2-normalized. Returns per-row losses (B,)."""
    B, d = q.shape
    nr, nc = B // br, B // bc
    kernel = functools.partial(_infonce_kernel, br=br, bc=bc, nc=nc,
                               inv_tau=1.0 / tau)
    return pl.pallas_call(
        kernel,
        grid=(nr, nc),
        in_specs=[
            pl.BlockSpec((br, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((br, 128), jnp.float32),
            pltpu.VMEM((br, 128), jnp.float32),
            pltpu.VMEM((br, 128), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k)
