"""Jit'd public wrappers around the Pallas kernels.

Each wrapper handles padding to TPU-aligned block shapes, dtype policy and
the CPU fallback (interpret mode). On CPU (no TPU platform) the wrappers
run the kernels with ``interpret=True`` so behaviour is identical
everywhere; on TPU the compiled kernels run natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import infonce as nce
from repro.kernels import mamba2_scan as ms
from repro.kernels import rmsnorm as rn


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, axis: int, mult: int, value=0.0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = None):
    """q: (B,S,Hq,hd); k,v: (B,T,Hkv,hd) -> (B,S,Hq,hd) (BSHD layout)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    scale = 1.0 / (q.shape[-1] ** 0.5)    # true head dim, pre-padding
    qt, S = _pad_to(qt, 2, bq)
    kt, T = _pad_to(kt, 2, bk)
    vt, _ = _pad_to(vt, 2, bk)
    qt, hd = _pad_to(qt, 3, 128)
    kt, _ = _pad_to(kt, 3, 128)
    vt, _ = _pad_to(vt, 3, 128)
    # padded kv positions must never win the softmax: causal masking already
    # excludes them for kpos > qpos; padded q rows are sliced off below.
    out = fa.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                  bq=bq, bk=bk, scale=scale, kv_len=T,
                                  interpret=interpret)
    return out[:, :, :S, :hd].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xh, dt, a, Bm, Cm, *, chunk: int = 128, interpret: bool = None):
    """Chunked SSD scan; see kernels.mamba2_scan."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    S = xh.shape[1]
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    return ms.ssd_scan_bshpn(xh, dt, a, Bm, Cm, chunk=c, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tau", "interpret"))
def fused_info_nce(q, k, tau: float = 0.2, interpret: bool = None):
    """Mean InfoNCE loss over L2-normalized rows of q against k."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    kn = k / jnp.maximum(jnp.linalg.norm(k, axis=-1, keepdims=True), 1e-12)
    B, d = qn.shape
    br = 128 if B % 128 == 0 else B
    qn, _ = _pad_to(qn, 1, 128)
    kn, _ = _pad_to(kn, 1, 128)
    rows = nce.info_nce_rows(qn.astype(jnp.float32), kn.astype(jnp.float32),
                             tau, br=br, bc=br, interpret=interpret)
    return jnp.mean(rows)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def fused_rmsnorm(x, scale, eps: float = 1e-5, interpret: bool = None):
    """x: (..., d) -> same shape."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    R = x2.shape[0]
    br = 256
    while R % br != 0:
        br //= 2
        if br == 1:
            break
    out = rn.rmsnorm_rows(x2, scale, eps, br=max(1, br), interpret=interpret)
    return out.reshape(shape)
