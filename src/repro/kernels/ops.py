"""Jit'd public wrappers around the Pallas kernels.

Each wrapper handles padding to TPU-aligned block shapes, dtype policy and
the CPU fallback (interpret mode). On CPU (no TPU platform) the wrappers
run the kernels with ``interpret=True`` so behaviour is identical
everywhere; on TPU the compiled kernels run natively.

The ``wire_*`` family (transport pack/unpack + fused codecs) adds a third
backend: on CPU hosts the Pallas interpreter proves semantics but is far
too slow to *be* the fast path, so by default the wrappers execute the
same fused algorithms through the numpy engine in ``hostwire`` (zero-copy
views + single-pass slot loops). Resolution order per call:

  TPU platform            -> native Pallas kernels
  ``interpret=True`` or   -> Pallas interpret mode (CI parity; also what
  ``REPRO_WIRE_INTERPRET``   the kernels CI job exercises)
  otherwise (CPU)         -> hostwire numpy fast path (returns numpy;
                             jax consumers convert lazily)
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as fa
from repro.kernels import hostwire as hw
from repro.kernels import infonce as nce
from repro.kernels import mamba2_scan as ms
from repro.kernels import pack as pk
from repro.kernels import rmsnorm as rn
from repro.kernels import wire_codecs as wc


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, axis: int, mult: int, value=0.0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = None):
    """q: (B,S,Hq,hd); k,v: (B,T,Hkv,hd) -> (B,S,Hq,hd) (BSHD layout)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    scale = 1.0 / (q.shape[-1] ** 0.5)    # true head dim, pre-padding
    qt, S = _pad_to(qt, 2, bq)
    kt, T = _pad_to(kt, 2, bk)
    vt, _ = _pad_to(vt, 2, bk)
    qt, hd = _pad_to(qt, 3, 128)
    kt, _ = _pad_to(kt, 3, 128)
    vt, _ = _pad_to(vt, 3, 128)
    # padded kv positions must never win the softmax: causal masking already
    # excludes them for kpos > qpos; padded q rows are sliced off below.
    out = fa.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                  bq=bq, bk=bk, scale=scale, kv_len=T,
                                  interpret=interpret)
    return out[:, :, :S, :hd].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xh, dt, a, Bm, Cm, *, chunk: int = 128, interpret: bool = None):
    """Chunked SSD scan; see kernels.mamba2_scan."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    S = xh.shape[1]
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    return ms.ssd_scan_bshpn(xh, dt, a, Bm, Cm, chunk=c, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tau", "interpret"))
def fused_info_nce(q, k, tau: float = 0.2, interpret: bool = None):
    """Mean InfoNCE loss over L2-normalized rows of q against k."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    kn = k / jnp.maximum(jnp.linalg.norm(k, axis=-1, keepdims=True), 1e-12)
    B, d = qn.shape
    br = 128 if B % 128 == 0 else B
    qn, _ = _pad_to(qn, 1, 128)
    kn, _ = _pad_to(kn, 1, 128)
    rows = nce.info_nce_rows(qn.astype(jnp.float32), kn.astype(jnp.float32),
                             tau, br=br, bc=br, interpret=interpret)
    return jnp.mean(rows)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def fused_rmsnorm(x, scale, eps: float = 1e-5, interpret: bool = None):
    """x: (..., d) -> same shape."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    R = x2.shape[0]
    br = 256
    while R % br != 0:
        br //= 2
        if br == 1:
            break
    out = rn.rmsnorm_rows(x2, scale, eps, br=max(1, br), interpret=interpret)
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# wire kernels: transport pack/unpack + fused codecs (three-way dispatch)
# ---------------------------------------------------------------------------
def _wire_mode(interpret) -> str:
    """'tpu' | 'interpret' | 'host' — see module docstring."""
    if interpret:
        return "interpret"
    if _on_tpu():
        return "tpu"
    if interpret is None and \
            os.environ.get("REPRO_WIRE_INTERPRET", "") not in ("", "0"):
        return "interpret"
    return "host"


@functools.lru_cache(maxsize=None)
def _pack_call(layout, total, interpret):
    return jax.jit(lambda srcs: pk.gather_pack(
        srcs, layout, total, interpret=interpret))


def wire_pack(srcs, layout, total: int, *, interpret=None):
    """Fused slot-table gather into the flat wire buffer. ``layout`` is
    the static ``((src_off, dst_off, size), ...)`` table; ``srcs`` are the
    matching leaves (any shape, raveled here). Returns (total,) fp32."""
    mode = _wire_mode(interpret)
    if mode == "host":
        return hw.pack([hw.leaf_view(s) for s in srcs], layout, total)
    if not layout:
        return jnp.zeros((total,), jnp.float32)
    srcs = [jnp.asarray(s).reshape(-1).astype(jnp.float32) for s in srcs]
    return _pack_call(tuple(layout), total, mode == "interpret")(srcs)


@functools.lru_cache(maxsize=None)
def _unpack_call(layout, interpret):
    def fn(flat, bases):
        dtypes = [b.dtype for b in bases]
        outs = pk.scatter_unpack(
            flat, [b.astype(jnp.float32) for b in bases], layout,
            interpret=interpret)
        return [o.astype(dt) for o, dt in zip(outs, dtypes)]
    return jax.jit(fn)


def wire_unpack(flat, bases, layout, *, interpret=None):
    """Fused slot-table scatter out of the flat wire buffer. ``layout``
    rows are ``(src_off, dst_off, size, full)`` — ``full`` marks slots
    covering their whole leaf (the host path returns those as zero-copy
    views). Returns the updated leaves, raveled, in layout order."""
    mode = _wire_mode(interpret)
    if mode == "host":
        return hw.unpack(np.asarray(flat), [hw.leaf_view(b) for b in bases],
                         layout)
    lay3 = tuple((s, d, n) for s, d, n, _ in layout)
    bases = [jnp.asarray(b).reshape(-1) for b in bases]
    return _unpack_call(lay3, mode == "interpret")(
        jnp.asarray(flat, jnp.float32), bases)


def wire_cast_encode(flat, dtype, *, interpret=None):
    """fp16/bf16 cast-on-the-wire encode (single pass either backend)."""
    if _wire_mode(interpret) == "host":
        return hw.cast_encode(np.asarray(flat), np.dtype(dtype))
    return jnp.asarray(flat).astype(dtype)


def wire_cast_decode(wire, *, interpret=None):
    if _wire_mode(interpret) == "host":
        return hw.cast_decode(np.asarray(wire))
    return jnp.asarray(wire).astype(jnp.float32)


@functools.lru_cache(maxsize=None)
def _int8_enc_call(segs, interpret):
    def fn(flat):
        qs, scales = [], []
        for off, size, ch, _ in segs:
            x = jax.lax.dynamic_slice(flat, (off,), (size,)).reshape(-1, ch)
            q, s = wc.int8_quant_matrix(x, interpret=interpret)
            qs.append(q.reshape(-1))
            scales.append(s)
        return jnp.concatenate(qs), jnp.concatenate(scales)
    return jax.jit(fn)


def wire_int8_encode(flat, segs, nscales: int, *, interpret=None):
    """Fused per-slot int8 quantization over the flat payload. ``segs``
    rows are ``(offset, size, channels, scale_offset)``. Returns
    (q int8 of ``flat``'s length, scales fp32 (nscales,))."""
    mode = _wire_mode(interpret)
    if mode == "host":
        return hw.int8_encode(np.asarray(flat), segs, nscales)
    return _int8_enc_call(tuple(segs), mode == "interpret")(
        jnp.asarray(flat, jnp.float32))


@functools.lru_cache(maxsize=None)
def _int8_dec_call(segs, total, interpret):
    def fn(q, scales):
        outs = []
        for off, size, ch, soff in segs:
            qi = jax.lax.dynamic_slice(q, (off,), (size,)).reshape(-1, ch)
            s = jax.lax.dynamic_slice(scales, (soff,), (ch,))
            outs.append(wc.int8_dequant_matrix(qi, s,
                                               interpret=interpret).reshape(-1))
        return jnp.concatenate(outs)
    return jax.jit(fn)


def wire_int8_decode(q, scales, segs, total: int, *, interpret=None):
    mode = _wire_mode(interpret)
    if mode == "host":
        return hw.int8_decode(np.asarray(q), np.asarray(scales), segs, total)
    return _int8_dec_call(tuple(segs), total, mode == "interpret")(
        jnp.asarray(q), jnp.asarray(scales))


@functools.lru_cache(maxsize=None)
def _topk_call(k, interpret):
    def fn(flat, ref, res):
        comp, absc = wc.compensate(flat, ref, res, interpret=interpret)
        vals, idx = jax.lax.top_k(absc, k)
        thresh = vals[k - 1]
        needed = (k - jnp.sum(absc > thresh)).astype(jnp.int32)
        new_res = wc.topk_ef_update(comp, thresh[None], needed[None],
                                    interpret=interpret)
        return idx.astype(jnp.int32), comp[idx], new_res
    return jax.jit(fn)


def wire_topk_encode_ef(flat, ref, res, k: int, *, interpret=None):
    """Fused top-k delta sparsification with on-chip error-feedback:
    compensated delta ``flat - ref (+ res)``, exact ``lax.top_k``-set
    selection, residual = the unselected (dropped) mass. ``res`` may be
    None (the mirror/broadcast path, no EF carry). Returns
    (idx int32 (k,), val fp32 (k,), new_residual fp32 (n,)) — wire ``idx``
    order may differ between backends; the selected set is identical."""
    mode = _wire_mode(interpret)
    if mode == "host":
        f = np.asarray(flat)
        comp = hw.wire_buffer(f.shape[0])
        np.subtract(f, np.asarray(ref), out=comp)
        if res is not None:
            comp += np.asarray(res)
        return hw.topk_encode_ef(comp, k)
    flat = jnp.asarray(flat, jnp.float32)
    ref = jnp.asarray(ref, jnp.float32)
    res = jnp.zeros_like(flat) if res is None else \
        jnp.asarray(res, jnp.float32)
    return _topk_call(k, mode == "interpret")(flat, ref, res)


def wire_topk_decode(idx, val, total: int, *, interpret=None):
    if _wire_mode(interpret) == "host":
        return hw.topk_decode(np.asarray(idx), np.asarray(val), total)
    return jnp.zeros((total,), jnp.float32).at[jnp.asarray(idx)].set(
        jnp.asarray(val))
