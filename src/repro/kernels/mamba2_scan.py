"""Pallas TPU chunked SSD scan (Mamba2).

One program per (batch, head, chunk); the chunk axis is sequential and the
(P, N) per-head state lives in VMEM scratch across chunk steps:

  grid = (B, H, S // chunk)                       — chunk axis "arbitrary"
  xh block (chunk, P), dt/a blocks (chunk, 128), B/C blocks (chunk, N)
  scratch  h (P, N) f32

Per chunk the intra-block term is two MXU matmuls ((Q,N)x(N,Q) and
(Q,Q)x(Q,P)) plus the decay mask; the inter-block term applies the carried
state. This mirrors ``repro.models.layers.mamba2.ssd_chunked`` (the oracle)
with the state kept resident in VMEM instead of a lax.scan carry.

dt/a are fed pre-broadcast to (S, 128) lanes so the kernel reads column 0 —
scalar-per-row values are lane-padded for TPU-friendly layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[:, 0].astype(jnp.float32)         # (Q,)
    a = a_ref[:, 0].astype(jnp.float32)           # (Q,) = dt * A  (negative)
    Bm = b_ref[...].astype(jnp.float32)           # (Q, N)
    Cm = c_ref[...].astype(jnp.float32)           # (Q, N)

    cum = jnp.cumsum(a)                           # (Q,)
    seg = cum[:, None] - cum[None, :]             # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    M = CB * L * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)
    # inter-chunk: y += exp(cum) * C @ h^T
    h = h_ref[...]                                # (P, N)
    y_off = jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + y_off * jnp.exp(cum)[:, None]
    y_ref[...] = y.astype(y_ref.dtype)
    # state update: h' = exp(sum a) h + sum_j w_j x_j B_j^T
    w = jnp.exp(cum[-1] - cum) * dt               # (Q,)
    st = jax.lax.dot_general(x * w[:, None], Bm, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P, N)
    h_ref[...] = h * jnp.exp(cum[-1]) + st


def ssd_scan_bshpn(xh, dt, a, Bm, Cm, *, chunk: int = 128,
                   interpret: bool = False):
    """xh: (B,S,H,P); dt,a: (B,S,H); Bm,Cm: (B,S,N) -> y: (B,S,H,P).

    ``a = dt * A`` (log-decay per step). S % chunk == 0.
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    # lane-pad per-row scalars to (B,S,H,128) for TPU layout
    dt_l = jnp.broadcast_to(dt[..., None], (B, S, H, 128))
    a_l = jnp.broadcast_to(a[..., None], (B, S, H, 128))
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, None, P),
                         lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((None, chunk, None, 128),
                         lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((None, chunk, None, 128),
                         lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, None, P),
                               lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), xh.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=(
                "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xh, dt_l, a_l, Bm, Cm)
