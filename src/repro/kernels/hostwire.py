"""Host (numpy) execution engine for the fused wire kernels on CPU.

The Pallas wire kernels in ``pack.py``/``wire_codecs.py`` compile natively
on TPU and run under ``interpret=True`` for CPU parity tests, but the
interpreter executes grid programs element-tile-at-a-time in Python — it
proves semantics, not speed. On CPU hosts the dispatch layer
(``ops.wire_*``) therefore runs the *same fused algorithms* here as flat
numpy passes over zero-copy views of the jax buffers:

  pack       one preallocated wire buffer + one ``copyto`` per slot from a
             zero-copy view of the leaf (``np.asarray`` on a CPU jax array
             aliases its memory) — no per-leaf intermediates, no
             concatenate. This is the same slot-table gather the Pallas
             kernel DMAs.
  unpack     whole-leaf slots are returned as views into the decoded
             buffer (zero copies); partial (stacked) slots copy the base
             once and overwrite the stage rows.
  int8       per-column absmax -> scale -> round/clip in one fused pass
             per slot, bit-identical to ``transport.Int8Codec`` (same
             IEEE fp32 ops; ``np.rint`` and XLA both round half-to-even).
  cast       fp16/bf16 round-trip via numpy/ml_dtypes casts (both numpy
             and XLA convert round-to-nearest-even).
  topk       exact ``lax.top_k`` selection semantics via a partition-based
             threshold: everything ``|x| > thresh`` plus the
             lowest-indexed ``|x| == thresh`` ties up to k, with the
             error-feedback residual produced by zeroing the selected
             entries in place. Wire ``idx`` order differs from
             ``lax.top_k`` (which sorts by magnitude) but the selected
             *set* is identical, so decoded payloads and residuals match.

Everything here returns numpy; jax consumers convert lazily on first use.

Wire buffers come from a refcount-aware pool (``wire_buffer``): payload
sized allocations exceed the allocator's mmap threshold, so a fresh
``np.empty`` per round pays a page fault per 4 KiB written (~3x the copy
cost). The pool hands back a previously used (warm) buffer only when its
refcount proves nothing else still holds it — escaping references (mirror
snapshots, zero-copy unpack views, stored residuals) automatically pin a
buffer out of reuse.
"""
from __future__ import annotations

import sys

import numpy as np

F32 = np.float32

_POOL: dict = {}
_POOL_DEPTH = 8


def wire_buffer(n: int) -> np.ndarray:
    """A (n,) fp32 buffer with warm pages, contents undefined. Reuses a
    pooled buffer iff only the pool references it (refcount == 3 here:
    pool list + loop variable + getrefcount argument)."""
    bufs = _POOL.setdefault(n, [])
    for b in bufs:
        if sys.getrefcount(b) == 3:
            return b
    b = np.empty(n, F32)
    bufs.append(b)
    if len(bufs) > _POOL_DEPTH:
        bufs.pop(0)
    return b


def leaf_view(a) -> np.ndarray:
    """Raveled zero-copy host view of a (CPU) jax or numpy array."""
    return np.asarray(a).reshape(-1)


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------
def pack(srcs, layout, total: int) -> np.ndarray:
    """``srcs``: raveled leaves; ``layout``: ((src_off, dst_off, size),...)
    -> (total,) fp32 wire buffer."""
    out = wire_buffer(total)
    for src, (src_off, dst_off, size) in zip(srcs, layout):
        np.copyto(out[dst_off:dst_off + size], src[src_off:src_off + size],
                  casting="unsafe")
    return out


def unpack(flat, bases, layout):
    """Reverse: ((src_off, dst_off, size, full), ...) rows; ``full`` slots
    come back as zero-copy views of ``flat``, partial slots as a copy of
    the base with the slot range overwritten. Returns raveled leaves."""
    outs = []
    for base, (src_off, dst_off, size, full) in zip(bases, layout):
        seg = flat[dst_off:dst_off + size]
        if full:
            if seg.dtype != base.dtype:
                seg = seg.astype(base.dtype)
            outs.append(seg)
        else:
            if base.dtype == F32:
                out = wire_buffer(base.shape[0])
                np.copyto(out, base)
            else:
                out = np.array(base)
            np.copyto(out[src_off:src_off + size], seg, casting="unsafe")
            outs.append(out)
    return outs


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------
def cast_encode(flat: np.ndarray, dtype) -> np.ndarray:
    return flat.astype(dtype)


def cast_decode(wire: np.ndarray) -> np.ndarray:
    out = wire_buffer(wire.shape[0])
    np.copyto(out, wire, casting="unsafe")
    return out


def int8_encode(flat, segs, nscales: int):
    """``segs``: ((offset, size, channels, scale_offset), ...) — one row
    per payload slot, matching ``transport._int8_channels``. Fused
    absmax -> scale -> round/clip per slot."""
    q = np.empty(flat.shape[0], np.int8)
    scales = np.empty(nscales, F32)
    for off, size, ch, soff in segs:
        seg = flat[off:off + size].reshape(-1, ch)
        amax = np.max(np.abs(seg), axis=0)
        scale = np.maximum(amax, 1e-12) / F32(127.0)
        scales[soff:soff + ch] = scale
        np.copyto(q[off:off + size].reshape(-1, ch),
                  np.clip(np.rint(seg / scale), -127, 127),
                  casting="unsafe")
    return q, scales


def int8_decode(q, scales, segs, total: int) -> np.ndarray:
    out = wire_buffer(total)
    for off, size, ch, soff in segs:
        seg = q[off:off + size].reshape(-1, ch).astype(F32)
        seg *= scales[soff:soff + ch]
        out[off:off + size] = seg.reshape(-1)
    return out


def topk_threshold(absc: np.ndarray, k: int):
    """k-th largest magnitude and the number of ``== thresh`` ties kept."""
    pivot = absc.shape[0] - k
    thresh = np.partition(absc, pivot)[pivot]
    n_gt = int(np.count_nonzero(absc > thresh))
    return thresh, k - n_gt


def topk_encode_ef(comp: np.ndarray, k: int):
    """Select ``lax.top_k``'s exact entry set from the compensated delta
    and apply the error-feedback update: returns (idx int32, val fp32,
    new_residual) with the selected entries zeroed out of ``comp``'s copy.
    """
    absc = np.abs(comp)
    thresh, needed = topk_threshold(absc, k)
    idx = np.flatnonzero(absc > thresh)
    if needed > 0:
        idx = np.concatenate([idx, np.flatnonzero(absc == thresh)[:needed]])
    new_res = wire_buffer(comp.shape[0])
    np.copyto(new_res, comp)
    new_res[idx] = 0.0
    return idx.astype(np.int32), comp[idx], new_res


def topk_decode(idx, val, total: int) -> np.ndarray:
    out = wire_buffer(total)
    out.fill(0.0)
    out[idx] = val
    return out
