"""Pallas TPU fused wire pack/unpack: slot-table gather/scatter DMA.

The transport's payload layout (``PayloadSpec``) is a static table of
slots — for each travelling leaf, an element range ``[src_off, src_off +
size)`` of the raveled leaf and a destination range ``[dst_off, dst_off +
size)`` of the flat wire buffer. The XLA path materializes one sliced/cast
intermediate per leaf and concatenates them (a fresh allocation + copy per
leaf, and ``concatenate`` is pathologically slow on CPU); these kernels
instead issue one async copy per slot inside a single grid program, moving
every slot HBM->HBM directly into (or out of) the flat buffer with no
intermediates.

``gather_pack``   n raveled fp32 leaves -> (total,) flat wire buffer.
``scatter_unpack`` flat wire buffer + n raveled base leaves -> n updated
                  leaves; each output aliases its base in place
                  (``input_output_aliases``) and only the slot range is
                  DMA'd over it, so untouched elements (rows outside the
                  stage range) keep the receiver's values.

Both kernels keep operands in ``ANY`` memory space: nothing is staged
through VMEM, the copies are pure DMA and the kernel body is just
start-all / wait-all over the slot table. Oracles: ``ref.wire_pack_ref`` /
``ref.wire_unpack_ref``; parity: tests/test_kernels.py (interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import make_compiler_params

WIRE_DTYPE = jnp.float32


def _pack_kernel(*refs, layout):
    srcs, out, sem = refs[:-2], refs[-2], refs[-1]
    copies = [
        pltpu.make_async_copy(
            srcs[i].at[pl.ds(src_off, size)],
            out.at[pl.ds(dst_off, size)],
            sem.at[i],
        )
        for i, (src_off, dst_off, size) in enumerate(layout)
    ]
    for c in copies:
        c.start()
    for c in copies:
        c.wait()


def gather_pack(srcs, layout, total: int, *, interpret: bool = False):
    """``srcs``: 1D fp32 leaves, one per layout row; ``layout``: static
    ``((src_off, dst_off, size), ...)``. Returns the (total,) wire buffer."""
    assert len(srcs) == len(layout) and layout
    kernel = functools.partial(_pack_kernel, layout=tuple(layout))
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY) for _ in srcs],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((total,), WIRE_DTYPE),
        scratch_shapes=[pltpu.SemaphoreType.DMA((len(layout),))],
        compiler_params=make_compiler_params(has_side_effects=True),
        interpret=interpret,
    )(*srcs)


def _unpack_kernel(*refs, layout):
    n = len(layout)
    flat, outs, sem = refs[0], refs[1 + n:1 + 2 * n], refs[-1]
    copies = [
        pltpu.make_async_copy(
            flat.at[pl.ds(dst_off, size)],
            outs[i].at[pl.ds(src_off, size)],
            sem.at[i],
        )
        for i, (src_off, dst_off, size) in enumerate(layout)
    ]
    for c in copies:
        c.start()
    for c in copies:
        c.wait()


def scatter_unpack(flat, bases, layout, *, interpret: bool = False):
    """Reverse of ``gather_pack``: write each slot range of ``flat`` over
    the matching range of its (aliased, donated) 1D base leaf. Returns the
    updated leaves in layout order."""
    assert len(bases) == len(layout) and layout
    kernel = functools.partial(_unpack_kernel, layout=tuple(layout))
    n = len(layout)
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * (1 + n),
        out_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * n,
        out_shape=[jax.ShapeDtypeStruct(b.shape, b.dtype) for b in bases],
        scratch_shapes=[pltpu.SemaphoreType.DMA((n,))],
        input_output_aliases={i + 1: i for i in range(n)},
        compiler_params=make_compiler_params(has_side_effects=True),
        interpret=interpret,
    )(flat, *bases)
