"""Pallas-TPU API compat across jax versions.

``pltpu.CompilerParams`` is the current spelling; on jax <= 0.4.x the same
dataclass is ``pltpu.TPUCompilerParams``. Kernels import it from here so
they run on both.
"""
import dataclasses

from jax.experimental.pallas import tpu as pltpu

try:
    CompilerParams = pltpu.CompilerParams
except AttributeError:
    try:
        CompilerParams = pltpu.TPUCompilerParams
    except AttributeError as e:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; this jax version is unsupported") from e

_PARAM_FIELDS = {f.name for f in dataclasses.fields(CompilerParams)}


def make_compiler_params(**kwargs):
    """CompilerParams dropping fields this jax version doesn't know (e.g.
    ``has_side_effects`` predates 0.5; older kernels still hint it for
    newer runtimes)."""
    return CompilerParams(
        **{k: v for k, v in kwargs.items() if k in _PARAM_FIELDS})
