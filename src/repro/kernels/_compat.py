"""Pallas-TPU API compat across jax versions.

``pltpu.CompilerParams`` is the current spelling; on jax <= 0.4.x the same
dataclass is ``pltpu.TPUCompilerParams``. Kernels import it from here so
they run on both.
"""
from jax.experimental.pallas import tpu as pltpu

try:
    CompilerParams = pltpu.CompilerParams
except AttributeError:
    try:
        CompilerParams = pltpu.TPUCompilerParams
    except AttributeError as e:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; this jax version is unsupported") from e
