# Pallas TPU kernels for the SSL/FL compute hot-spots, each validated in
# interpret mode against the pure-jnp oracle in ref.py:
#   flash_attention — GQA causal/window online-softmax attention
#   mamba2_scan     — chunked SSD scan with VMEM-resident state
#   infonce         — fused (B,B) contrastive logits + cross-entropy
#   rmsnorm         — fused row-blocked RMSNorm
#   pack            — transport wire pack/unpack (slot-table gather/scatter DMA)
#   wire_codecs     — fused int8 per-channel quant + top-k error-feedback
# The wire_* wrappers dispatch TPU -> native Pallas, interpret mode for CI,
# and a numpy host engine (hostwire) as the CPU fast path; see ops.py.
from repro.kernels.ops import (  # noqa: F401
    flash_attention, fused_info_nce, fused_rmsnorm, ssd_scan,
    wire_cast_decode, wire_cast_encode, wire_int8_decode, wire_int8_encode,
    wire_pack, wire_topk_decode, wire_topk_encode_ef, wire_unpack)
