# Pallas TPU kernels for the SSL/FL compute hot-spots, each validated in
# interpret mode against the pure-jnp oracle in ref.py:
#   flash_attention — GQA causal/window online-softmax attention
#   mamba2_scan     — chunked SSD scan with VMEM-resident state
#   infonce         — fused (B,B) contrastive logits + cross-entropy
#   rmsnorm         — fused row-blocked RMSNorm
from repro.kernels.ops import (  # noqa: F401
    flash_attention, fused_info_nce, fused_rmsnorm, ssd_scan)
