"""Pallas TPU fused wire codecs: int8 per-channel quant + top-k EF update.

int8 (``int8_quant_matrix`` / ``int8_dequant_matrix``): the XLA codec path
runs a separate abs/max reduce, scale divide and round per slot, each
materializing intermediates. Here one kernel per slot matrix does the
whole thing in a single grid program: a two-phase sequential grid over row
tiles — phase 0 accumulates the per-column absmax into a persistent VMEM
scratch, phase 1 turns it into the dequant scale (``max(amax, 1e-12) /
127``) and emits the clipped/rounded int8 payload — so each element is
read exactly twice and written once, with no dense fp32 intermediates.
The math is bit-identical to ``transport.Int8Codec`` (same IEEE fp32 ops,
round-half-even).

top-k (``compensate`` / ``topk_ef_update``): the XLA path materializes the
delta, the compensated delta, |delta| and the post-selection residual as
separate dense buffers. ``compensate`` fuses delta + error-feedback add +
|.| into one pass; ``topk_ef_update`` applies the residual update on-chip:
given the k-th magnitude threshold it zeroes every *selected* entry of the
compensated delta in one pass, using a sequential-grid running count so
``|x| == threshold`` ties are broken exactly like ``lax.top_k`` (lowest
index first, up to the ``needed`` count). What's left *is* the new
error-feedback residual — dropped mass, nothing else.

Oracles in ref.py; parity tests in tests/test_kernels.py (interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import make_compiler_params

LANE = 128


def _pad2(x, br):
    """Pad (R, C) up to (multiple of br, multiple of LANE)."""
    R, C = x.shape
    pr, pc = (-R) % br, (-C) % LANE
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x, R, C


# ---------------------------------------------------------------------------
# int8 per-channel (per-column) symmetric quantization
# ---------------------------------------------------------------------------
def _int8_quant_kernel(x_ref, q_ref, s_ref, amax_ref):
    phase = pl.program_id(0)
    tile = pl.program_id(1)

    @pl.when((phase == 0) & (tile == 0))
    def _init():
        amax_ref[...] = jnp.zeros_like(amax_ref)

    x = x_ref[...]

    @pl.when(phase == 0)
    def _reduce():
        amax_ref[...] = jnp.maximum(
            amax_ref[...], jnp.max(jnp.abs(x), axis=0, keepdims=True))

    @pl.when(phase == 1)
    def _quantize():
        scale = jnp.maximum(amax_ref[...], 1e-12) / 127.0
        s_ref[...] = scale
        q_ref[...] = jnp.clip(jnp.round(x / scale),
                              -127, 127).astype(jnp.int8)


def int8_quant_matrix(x, *, br: int = 256, interpret: bool = False):
    """x: (R, C) fp32 -> (q (R, C) int8, scale (C,) fp32), scale per column
    (``= max(absmax, 1e-12) / 127``), q = clip(round(x / scale))."""
    xp, R, C = _pad2(x, br)
    Rp, Cp = xp.shape
    q, s = pl.pallas_call(
        _int8_quant_kernel,
        grid=(2, Rp // br),
        in_specs=[pl.BlockSpec((br, Cp), lambda p, i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, Cp), lambda p, i: (i, 0)),
            pl.BlockSpec((1, Cp), lambda p, i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, Cp), jnp.int8),
            jax.ShapeDtypeStruct((1, Cp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, Cp), jnp.float32)],
        compiler_params=make_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(xp)
    return q[:R, :C], s[0, :C]


def _int8_dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def int8_dequant_matrix(q, scale, *, br: int = 256,
                        interpret: bool = False):
    """q: (R, C) int8, scale: (C,) -> (R, C) fp32 in one fused pass."""
    qp, R, C = _pad2(q, br)
    Rp, Cp = qp.shape
    sp = jnp.pad(scale.reshape(1, -1), ((0, 0), (0, Cp - C)))
    out = pl.pallas_call(
        _int8_dequant_kernel,
        grid=(Rp // br,),
        in_specs=[
            pl.BlockSpec((br, Cp), lambda i: (i, 0)),
            pl.BlockSpec((1, Cp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, Cp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, Cp), jnp.float32),
        compiler_params=make_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(qp, sp)
    return out[:R, :C]


# ---------------------------------------------------------------------------
# top-k delta sparsification with on-chip error-feedback update
# ---------------------------------------------------------------------------
def _compensate_kernel(f_ref, r_ref, e_ref, c_ref, a_ref):
    c = f_ref[...] - r_ref[...] + e_ref[...]
    c_ref[...] = c
    a_ref[...] = jnp.abs(c)


def compensate(flat, ref, res, *, br: int = 256, interpret: bool = False):
    """Fused (flat - ref + res, |flat - ref + res|) over 1D fp32 buffers:
    the delta-vs-reference and error-feedback add in one elementwise pass,
    emitting the magnitudes the top-k selection ranks on."""
    n = flat.shape[0]
    cols = LANE
    rows = -(-n // cols)
    shape2 = (rows, cols)

    def as2d(v):
        return jnp.pad(v, (0, rows * cols - n)).reshape(shape2)

    f2, r2, e2 = as2d(flat), as2d(ref), as2d(res)
    f2, R, C = _pad2(f2, br)
    r2, _, _ = _pad2(r2, br)
    e2, _, _ = _pad2(e2, br)
    Rp, Cp = f2.shape
    c2, a2 = pl.pallas_call(
        _compensate_kernel,
        grid=(Rp // br,),
        in_specs=[pl.BlockSpec((br, Cp), lambda i: (i, 0))] * 3,
        out_specs=[pl.BlockSpec((br, Cp), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((Rp, Cp), jnp.float32)] * 2,
        compiler_params=make_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(f2, r2, e2)
    return c2.reshape(-1)[:n], a2.reshape(-1)[:n]


def _ef_update_kernel(c_ref, a_ref, t_ref, k_ref, o_ref, cnt_ref):
    tile = pl.program_id(0)

    @pl.when(tile == 0)
    def _init():
        cnt_ref[0] = 0

    c = c_ref[...]
    a = a_ref[...]
    thresh = t_ref[0]
    needed = k_ref[0]
    gt = a > thresh
    eq = a == thresh
    # global row-major rank (1-based) of each ==threshold entry: within-row
    # cumsum + exclusive prefix of per-row totals + the running count
    # carried across tiles in SMEM (the grid is sequential).
    eqi = eq.astype(jnp.int32)
    row = jnp.cumsum(eqi, axis=1)
    row_tot = row[:, -1:]
    prior = jnp.cumsum(row_tot, axis=0) - row_tot
    rank = row + prior + cnt_ref[0]
    selected = gt | (eq & (rank <= needed))
    o_ref[...] = jnp.where(selected, 0.0, c)
    cnt_ref[0] = cnt_ref[0] + row[-1, -1] + prior[-1, 0]


def topk_ef_update(comp, thresh, needed, *, br: int = 256,
                   interpret: bool = False):
    """New error-feedback residual in one pass: zero the selected entries
    of the compensated delta ``comp`` — everything with ``|x| > thresh``
    plus the lowest-indexed ``|x| == thresh`` entries up to ``needed``
    (exactly ``lax.top_k``'s tie order) — and keep the rest (the dropped
    mass). ``thresh`` is (1,) fp32, ``needed`` is (1,) int32."""
    n = comp.shape[0]
    cols = LANE
    rows = -(-n // cols)

    def as2d(v):
        return jnp.pad(v, (0, rows * cols - n)).reshape(rows, cols)

    c2, a2 = as2d(comp), as2d(jnp.abs(comp))
    c2, R, C = _pad2(c2, br)
    a2, _, _ = _pad2(a2, br)
    Rp, Cp = c2.shape
    out = pl.pallas_call(
        _ef_update_kernel,
        grid=(Rp // br,),
        in_specs=[
            pl.BlockSpec((br, Cp), lambda i: (i, 0)),
            pl.BlockSpec((br, Cp), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((br, Cp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, Cp), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        compiler_params=make_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(c2, a2, thresh.reshape(1), needed.reshape(1))
    return out.reshape(-1)[:n]
