"""Pure-jnp oracles for every Pallas kernel (CPU ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.sdpa import sdpa_dense
from repro.models.layers.mamba2 import ssd_chunked


def sdpa_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,Hq,S,hd); k,v: (B,Hkv,T,hd) -> (B,Hq,S,hd)."""
    Hq, Hkv = q.shape[1], k.shape[1]
    rep = Hq // Hkv
    kk = jnp.repeat(k, rep, axis=1) if rep > 1 else k
    vv = jnp.repeat(v, rep, axis=1) if rep > 1 else v
    out = sdpa_dense(q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
                     vv.transpose(0, 2, 1, 3), causal=causal, window=window,
                     compute_dtype=jnp.float32)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def ssd_scan_ref(xh, dt, a, Bm, Cm, *, chunk: int = 128):
    """Matches kernels.mamba2_scan.ssd_scan_bshpn (a = dt * A)."""
    A_unit = jnp.ones((xh.shape[2],), jnp.float32)
    # ssd_chunked expects dt and A separately with a = dt*A; reuse it by
    # passing dt=a ("dt"=log-decay) only for the decay term. Simpler: call
    # with dt_orig and A derived per-step is impossible (A varies); instead
    # re-derive: ssd_chunked uses a = dt * A internally, so feed dt and a/dt.
    # To stay exact we inline the same math with explicit a.
    y, _ = _ssd_explicit(xh, dt, a, Bm, Cm, chunk)
    return y.astype(xh.dtype)


def _ssd_explicit(xh, dt, a, Bm, Cm, chunk):
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    xs = (
        xh.astype(jnp.float32).reshape(Bsz, nc, chunk, H, P)
        .transpose(1, 0, 2, 3, 4),
        dt.astype(jnp.float32).reshape(Bsz, nc, chunk, H).transpose(1, 0, 2, 3),
        a.astype(jnp.float32).reshape(Bsz, nc, chunk, H).transpose(1, 0, 2, 3),
        Bm.astype(jnp.float32).reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3),
        Cm.astype(jnp.float32).reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3),
    )
    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    i = jnp.arange(chunk)
    causal = (i[:, None] >= i[None, :])

    def step(h, inp):
        x_c, dt_c, a_c, B_c, C_c = inp
        cum = jnp.cumsum(a_c, axis=1)
        seg = cum[:, :, None, :] - cum[:, None, :, :]
        L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        CB = jnp.einsum("bin,bjn->bij", C_c, B_c)
        M = CB[..., None] * L * dt_c[:, None, :, :]
        y_diag = jnp.einsum("bijh,bjhp->bihp", M, x_c)
        y_off = jnp.einsum("bin,bhpn->bihp", C_c, h) * \
            jnp.exp(cum)[..., None]
        w = jnp.exp(cum[:, -1:, :] - cum) * dt_c
        st = jnp.einsum("bjh,bjn,bjhp->bhpn", w, B_c, x_c)
        h_new = h * jnp.exp(jnp.sum(a_c, axis=1))[:, :, None, None] + st
        return h_new, y_diag + y_off

    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return y, h_final


def info_nce_rows_ref(q, k, tau: float):
    """Per-row InfoNCE (inputs L2-normalized). Returns (B,) fp32."""
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) / tau
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.diagonal(logits)
    return logz - gold


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


# -- wire kernels (pack/unpack + fused codecs) ------------------------------
def wire_pack_ref(srcs, layout, total: int):
    """Slot-table gather: layout rows are (src_off, dst_off, size)."""
    out = jnp.zeros((total,), jnp.float32)
    for src, (src_off, dst_off, size) in zip(srcs, layout):
        seg = jax.lax.dynamic_slice(src.astype(jnp.float32).reshape(-1),
                                    (src_off,), (size,))
        out = jax.lax.dynamic_update_slice(out, seg, (dst_off,))
    return out


def wire_unpack_ref(flat, bases, layout):
    """Slot-table scatter: each slot range of ``flat`` overwrites the
    matching range of its 1D base leaf."""
    outs = []
    for base, (src_off, dst_off, size) in zip(bases, layout):
        seg = jax.lax.dynamic_slice(flat, (dst_off,), (size,))
        outs.append(jax.lax.dynamic_update_slice(
            base, seg.astype(base.dtype), (src_off,)))
    return outs


def int8_quant_ref(x):
    """x: (R, C) fp32 -> (q int8, per-column scale fp32); the exact
    ``transport.Int8Codec`` math."""
    amax = jnp.max(jnp.abs(x), axis=0)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequant_ref(q, scale):
    return q.astype(jnp.float32) * scale


def topk_ef_ref(flat, ref, res, k: int):
    """Full XLA top-k upload semantics: compensated delta, ``lax.top_k``
    selection, error-feedback residual, and the decoded dense payload.
    Returns (idx, val, new_res, dec)."""
    comp = flat - ref + res
    _, idx = jax.lax.top_k(jnp.abs(comp), k)
    val = comp[idx]
    new_res = comp.at[idx].set(0.0)
    dec = jnp.zeros_like(comp).at[idx].set(val)
    return idx.astype(jnp.int32), val, new_res, dec
