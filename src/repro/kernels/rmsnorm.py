"""Pallas TPU fused RMSNorm.

Row-blocked: each program normalizes a (br, d) tile fully in VMEM (one HBM
read + one write; XLA otherwise materializes the fp32 upcast). d is the
model dim (always 128-aligned for the assigned architectures).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_rows(x, scale, eps: float = 1e-5, *, br: int = 256,
                 interpret: bool = False):
    """x: (R, d); scale: (d,). Returns (R, d) of x.dtype."""
    R, d = x.shape
    br = min(br, R)
    assert R % br == 0, (R, br)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, scale)
