"""Learning-rate strategies (paper Section 5.9, Fig. 12).

``cosine``  — one cosine decay over the whole FL process (paper default).
``fixed``   — constant base LR (best for FedMoCo-LW per Fig. 8).
``cyclic``  — cosine decay restarted within every layer-wise stage.

The paper linearly scales: lr = base_lr * batch_size / 256.
"""
from __future__ import annotations

import jax.numpy as jnp


def scaled_base_lr(base_lr: float, batch_size: int) -> float:
    return base_lr * batch_size / 256.0


def learning_rate(step, total_steps: int, base_lr: float,
                  schedule: str = "cosine", *, stage_step=None,
                  stage_total: int = 0, warmup_steps: int = 0):
    """step: global step (int or traced). Returns fp32 LR.

    For ``cyclic``, ``stage_step``/``stage_total`` give the position within
    the current layer-wise stage.
    """
    step = jnp.asarray(step, jnp.float32)
    lr = jnp.float32(base_lr)
    if schedule == "fixed":
        out = lr
    elif schedule == "cosine":
        t = jnp.clip(step / jnp.maximum(1.0, float(total_steps)), 0.0, 1.0)
        out = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    elif schedule == "cyclic":
        ss = jnp.asarray(stage_step if stage_step is not None else step,
                         jnp.float32)
        t = jnp.clip(ss / jnp.maximum(1.0, float(stage_total or total_steps)),
                     0.0, 1.0)
        out = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    else:
        raise ValueError(schedule)
    if warmup_steps:
        out = out * jnp.clip(step / float(warmup_steps), 0.0, 1.0)
    return out
