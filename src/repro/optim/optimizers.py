"""Optimizers (self-contained, optax-free): AdamW, Adafactor, SGD-momentum.

All are expressed as ``init(params) -> state`` / ``update(grads, state,
params, lr) -> (new_params, new_state)`` pairs over pytrees, jit- and
pjit-friendly (states shard like their parameters).

Freeze masking: layer-wise training must not update frozen layers. The
forward pass already blocks gradients with ``stop_gradient`` (so frozen
grads are exactly zero), but AdamW's weight decay and Adafactor's update
rule would still move frozen weights — ``mask`` zeroes the whole update.

Adafactor (Shazeer & Stern, 2018) keeps factored second-moment estimates
(row/col means) for matrices — the optimizer-memory fit story for the
123B/236B/400B assigned architectures on 256 chips.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]    # (grads, state, params, lr, mask=None)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def _masked(updates, mask):
    if mask is None:
        return updates
    return jax.tree.map(lambda u, m: u * m, updates, mask)


def freeze_tree_mask(params, predicate):
    """mask leaf = 0.0 where predicate(path) says frozen, else 1.0.

    predicate receives the jax key-path tuple of each leaf.
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, a: jnp.zeros((), a.dtype) if predicate(path)
        else jnp.ones((), a.dtype), params)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def make_adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0, grad_clip=0.0):
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)  # noqa: E731
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr, mask=None):
        if grad_clip:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        c = state["count"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        updates = jax.tree.map(
            lambda m, v, p: -lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                                   + weight_decay * p.astype(jnp.float32)),
            mu, nu, params)
        updates = _masked(updates, mask)
        return apply_updates(params, updates), \
            {"mu": mu, "nu": nu, "count": c}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no first moment)
# ---------------------------------------------------------------------------
def make_adafactor(eps=1e-30, clip_threshold=1.0, decay_rate=0.8,
                   weight_decay=0.0, min_dim_size_to_factor=128):
    def _factored(shape):
        return len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor \
            and shape[-2] >= min_dim_size_to_factor

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"m": jax.tree.map(leaf, params,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray)),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr, mask=None):
        c = state["count"] + 1
        beta = 1.0 - c.astype(jnp.float32) ** (-decay_rate)

        def leaf(g, st, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in st:
                vr = beta * st["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * st["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                pre = (vr / denom)[..., None] * vc[..., None, :]
                u = g * jax.lax.rsqrt(pre + eps)
                new = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new = {"v": v}
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            upd = -lr * (u + weight_decay * p.astype(jnp.float32))
            return upd, new

        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = tdef.flatten_up_to(state["m"])
        flat_p = jax.tree.leaves(params)
        outs = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        updates = tdef.unflatten([o[0] for o in outs])
        new_m = tdef.unflatten([o[1] for o in outs])
        updates = _masked(updates, mask)
        return apply_updates(params, updates), {"m": new_m, "count": c}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# SGD with momentum (supervised FL baseline)
# ---------------------------------------------------------------------------
def make_sgdm(momentum=0.9, weight_decay=0.0):
    def init(params):
        return {"v": jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}

    def update(grads, state, params, lr, mask=None):
        v = jax.tree.map(
            lambda v, g, p: momentum * v + g.astype(jnp.float32)
            + weight_decay * p.astype(jnp.float32),
            state["v"], grads, params)
        updates = _masked(jax.tree.map(lambda v: -lr * v, v), mask)
        return apply_updates(params, updates), {"v": v}

    return Optimizer(init, update)


def make_optimizer(train_cfg) -> Optimizer:
    if train_cfg.optimizer == "adamw":
        return make_adamw(train_cfg.b1, train_cfg.b2, train_cfg.eps,
                          train_cfg.weight_decay, train_cfg.grad_clip)
    if train_cfg.optimizer == "adafactor":
        return make_adafactor(weight_decay=train_cfg.weight_decay)
    if train_cfg.optimizer == "sgdm":
        return make_sgdm(weight_decay=train_cfg.weight_decay)
    raise ValueError(train_cfg.optimizer)
