from repro.optim.optimizers import (  # noqa: F401
    Optimizer, make_optimizer, apply_updates, freeze_tree_mask)
from repro.optim.schedules import learning_rate  # noqa: F401
