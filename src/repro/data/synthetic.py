"""Synthetic datasets standing in for STL-10 / CIFAR / Tiny-ImageNet.

No dataset downloads exist in this offline container, so we generate
*structured* synthetic data: each class is a distinct procedural texture
(frequency/orientation/color signature) plus noise. Linear separability of
classes in pixel space is deliberately broken by random phase so that
representation learning is non-trivial but learnable — good enough to
exercise every system path and observe loss decrease at smoke scale.

Token pipelines generate Zipf-distributed sequences with Markov structure
for the LM-family architectures.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def synthetic_images(key, n: int, num_classes: int = 10, size: int = 32):
    """Returns (images (n, size, size, 3) float32 in [0,1], labels (n,))."""
    kl, kp, kn = jax.random.split(key, 3)
    labels = jax.random.randint(kl, (n,), 0, num_classes)
    freqs = 1.0 + jnp.arange(num_classes, dtype=jnp.float32) % 5
    orient = (jnp.arange(num_classes, dtype=jnp.float32)
              * (np.pi / num_classes))
    colors = jax.random.uniform(jax.random.PRNGKey(7),
                                (num_classes, 3), minval=0.2, maxval=1.0)
    yy, xx = jnp.meshgrid(jnp.arange(size, dtype=jnp.float32),
                          jnp.arange(size, dtype=jnp.float32), indexing="ij")

    def one(label, phase, noise):
        f, th = freqs[label], orient[label]
        wave = jnp.sin(2 * np.pi * f / size *
                       (xx * jnp.cos(th) + yy * jnp.sin(th)) + phase)
        base = 0.5 + 0.35 * wave
        img = base[..., None] * colors[label][None, None, :]
        return jnp.clip(img + 0.08 * noise, 0.0, 1.0)

    phases = jax.random.uniform(kp, (n,), maxval=2 * np.pi)
    noise = jax.random.normal(kn, (n, size, size, 3))
    return jax.vmap(one)(labels, phases, noise), labels


def synthetic_tokens(key, n_seqs: int, seq_len: int, vocab_size: int):
    """Zipf marginals with first-order Markov mixing; labels = next token."""
    kz, km = jax.random.split(key)
    ranks = jnp.arange(1, vocab_size + 1, dtype=jnp.float32)
    logits = -1.1 * jnp.log(ranks)
    first = jax.random.categorical(kz, logits, shape=(n_seqs, 1))

    def step(tok, k):
        # next token correlates with previous (shifted zipf)
        nxt = (tok + jax.random.categorical(k, logits, shape=tok.shape)) \
            % vocab_size
        return nxt, nxt

    keys = jax.random.split(km, seq_len - 1)
    _, rest = jax.lax.scan(step, first[:, 0], keys)
    toks = jnp.concatenate([first, rest.T], axis=1)
    labels = jnp.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
    return toks.astype(jnp.int32), labels.astype(jnp.int32)


def client_batches(data, idx, batch_size: int, key):
    """Yield shuffled batches of data[idx] (one local epoch)."""
    perm = jax.random.permutation(key, idx.shape[0])
    idx = idx[perm]
    n = (idx.shape[0] // batch_size) * batch_size
    for i in range(0, n, batch_size):
        sel = idx[i:i + batch_size]
        yield jax.tree.map(lambda a: a[sel], data)
