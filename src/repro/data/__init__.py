from repro.data.augment import two_views  # noqa: F401
from repro.data.partition import dirichlet_partition, iid_partition  # noqa: F401
from repro.data.synthetic import (  # noqa: F401
    synthetic_images, synthetic_tokens, client_batches)
