"""FL data partitioners: IID and Dirichlet label-skew (paper Section 5.6).

Dirichlet: for each class c, draw p ~ Dir(beta * 1_N) and split that class's
samples across the N clients proportionally (Hsu et al.). Lower beta =>
stronger heterogeneity (Fig. A.16).
"""
from __future__ import annotations

import numpy as np


def iid_partition(n_samples: int, n_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_samples)
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def dirichlet_partition(labels, n_clients: int, beta: float, seed: int = 0,
                        min_per_client: int = 1):
    labels = np.asarray(labels)
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    shards = [[] for _ in range(n_clients)]
    for c in classes:
        idx = rng.permutation(np.where(labels == c)[0])
        p = rng.dirichlet(np.full(n_clients, beta))
        cuts = (np.cumsum(p)[:-1] * len(idx)).astype(int)
        for i, part in enumerate(np.split(idx, cuts)):
            shards[i].extend(part.tolist())
    # guarantee non-empty clients (move from the largest shard)
    sizes = [len(s) for s in shards]
    for i in range(n_clients):
        while len(shards[i]) < min_per_client:
            j = int(np.argmax([len(s) for s in shards]))
            shards[i].append(shards[j].pop())
    return [np.sort(np.array(s, dtype=np.int64)) for s in shards]
