"""FL data partitioners: IID and Dirichlet label-skew (paper Section 5.6).

Dirichlet: for each class c, draw p ~ Dir(beta * 1_N) and split that class's
samples across the N clients proportionally (Hsu et al.). Lower beta =>
stronger heterogeneity (Fig. A.16).

``stack_shards`` turns a list of ragged per-client index shards into one
client-stacked array (leading axis = client) for the vectorized engine
(``repro.federated.engine``): shards shorter than the longest one are padded
by wrapping around their own indices, and the true shard lengths are
returned so callers can mask out padded positions.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def iid_partition(n_samples: int, n_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_samples)
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def dirichlet_partition(labels, n_clients: int, beta: float, seed: int = 0,
                        min_per_client: int = 1):
    labels = np.asarray(labels)
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    shards = [[] for _ in range(n_clients)]
    for c in classes:
        idx = rng.permutation(np.where(labels == c)[0])
        p = rng.dirichlet(np.full(n_clients, beta))
        cuts = (np.cumsum(p)[:-1] * len(idx)).astype(int)
        for i, part in enumerate(np.split(idx, cuts)):
            shards[i].extend(part.tolist())
    # guarantee non-empty clients (move from the largest shard)
    sizes = [len(s) for s in shards]
    for i in range(n_clients):
        while len(shards[i]) < min_per_client:
            j = int(np.argmax([len(s) for s in shards]))
            shards[i].append(shards[j].pop())
    return [np.sort(np.array(s, dtype=np.int64)) for s in shards]


def stack_shards(pool, client_indices):
    """Stack per-client shards of ``pool`` on a leading client axis.

    pool: array or pytree of arrays with a shared leading sample axis;
    client_indices: list of N per-client index arrays (ragged). Returns
    ``(stacked, lengths)`` where every leaf of ``stacked`` has shape
    ``(N, n_max, ...)`` and ``lengths`` is the ``(N,)`` array of true shard
    sizes. Ragged shards are padded with wrapped-around copies of their own
    samples, so padded rows are always valid data — the engine's step
    validity mask (not the padding value) is what preserves training
    semantics.
    """
    import jax

    lengths = np.asarray([len(ix) for ix in client_indices], np.int64)
    if lengths.min() < 1:
        raise ValueError("every client shard must be non-empty")
    n_max = int(lengths.max())
    padded = np.stack([
        np.pad(np.asarray(ix, np.int64), (0, n_max - len(ix)), mode="wrap")
        for ix in client_indices])
    idx = jnp.asarray(padded)
    return jax.tree.map(lambda a: a[idx], pool), lengths
