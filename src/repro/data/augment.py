"""JAX image augmentations for SSL view creation (paper Section 5.1).

The MoCo v3 recipe: random resized crop, color jitter, grayscale,
horizontal flip, Gaussian blur, solarization — all jit-able, vmapped over
the batch, so view creation runs inside the client's compiled train step
(no host-side dataloader, a TPU-adaptation noted in DESIGN.md).

Images are (H, W, 3) float32 in [0, 1].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _bilinear_resize(img, out_h: int, out_w: int):
    return jax.image.resize(img, (out_h, out_w, img.shape[-1]), "bilinear")


def random_resized_crop(key, img, scale=(0.2, 1.0)):
    H, W, _ = img.shape
    k1, k2, k3 = jax.random.split(key, 3)
    area = jax.random.uniform(k1, (), minval=scale[0], maxval=scale[1])
    side = jnp.sqrt(area)
    ch = jnp.maximum(1, (side * H).astype(jnp.int32))
    cw = jnp.maximum(1, (side * W).astype(jnp.int32))
    y0 = jax.random.randint(k2, (), 0, H) % jnp.maximum(1, H - ch + 1)
    x0 = jax.random.randint(k3, (), 0, W) % jnp.maximum(1, W - cw + 1)
    # gather-based crop+resize (dynamic sizes are not jit-able; sample a
    # coordinate grid instead — equivalent to crop + bilinear resize)
    ys = y0 + (jnp.arange(H) + 0.5) / H * ch - 0.5
    xs = x0 + (jnp.arange(W) + 0.5) / W * cw - 0.5
    y_lo = jnp.clip(jnp.floor(ys).astype(jnp.int32), 0, H - 1)
    x_lo = jnp.clip(jnp.floor(xs).astype(jnp.int32), 0, W - 1)
    y_hi = jnp.clip(y_lo + 1, 0, H - 1)
    x_hi = jnp.clip(x_lo + 1, 0, W - 1)
    wy = (ys - y_lo)[:, None, None]
    wx = (xs - x_lo)[None, :, None]
    g = lambda yy, xx: img[yy][:, xx]        # noqa: E731
    out = (g(y_lo, x_lo) * (1 - wy) * (1 - wx) + g(y_lo, x_hi) * (1 - wy) * wx
           + g(y_hi, x_lo) * wy * (1 - wx) + g(y_hi, x_hi) * wy * wx)
    return out


def color_jitter(key, img, strength=0.4):
    kb, kc, ks_, kh = jax.random.split(key, 4)
    b = 1.0 + jax.random.uniform(kb, (), minval=-strength, maxval=strength)
    c = 1.0 + jax.random.uniform(kc, (), minval=-strength, maxval=strength)
    s = 1.0 + jax.random.uniform(ks_, (), minval=-strength, maxval=strength)
    img = img * b
    mean = jnp.mean(img, axis=(0, 1), keepdims=True)
    img = (img - mean) * c + mean
    gray = jnp.mean(img, axis=-1, keepdims=True)
    img = gray + (img - gray) * s
    # cheap hue-ish channel roll mix
    h = jax.random.uniform(kh, (), minval=-0.1, maxval=0.1)
    img = img * (1 - jnp.abs(h)) + jnp.roll(img, 1, axis=-1) * jnp.abs(h)
    return jnp.clip(img, 0.0, 1.0)


def random_grayscale(key, img, p=0.2):
    gray = jnp.broadcast_to(jnp.mean(img, axis=-1, keepdims=True), img.shape)
    return jnp.where(jax.random.uniform(key) < p, gray, img)


def random_hflip(key, img, p=0.5):
    return jnp.where(jax.random.uniform(key) < p, img[:, ::-1], img)


def gaussian_blur(key, img, p=0.5, sigma_range=(0.1, 2.0), ksize: int = 5):
    k1, k2 = jax.random.split(key)
    sigma = jax.random.uniform(k1, (), minval=sigma_range[0],
                               maxval=sigma_range[1])
    r = ksize // 2
    xs = jnp.arange(-r, r + 1, dtype=jnp.float32)
    w = jnp.exp(-0.5 * (xs / sigma) ** 2)
    w = w / jnp.sum(w)
    pad = [(r, r), (0, 0), (0, 0)]
    v = jnp.pad(img, pad, mode="edge")
    v = sum(v[i:i + img.shape[0]] * w[i] for i in range(ksize))
    pad = [(0, 0), (r, r), (0, 0)]
    hz = jnp.pad(v, pad, mode="edge")
    hz = sum(hz[:, i:i + img.shape[1]] * w[i] for i in range(ksize))
    return jnp.where(jax.random.uniform(k2) < p, hz, img)


def solarize(key, img, p=0.2, threshold=0.5):
    sol = jnp.where(img >= threshold, 1.0 - img, img)
    return jnp.where(jax.random.uniform(key) < p, sol, img)


def augment_one(key, img):
    ks = jax.random.split(key, 6)
    img = random_resized_crop(ks[0], img)
    img = color_jitter(ks[1], img)
    img = random_grayscale(ks[2], img)
    img = random_hflip(ks[3], img)
    img = gaussian_blur(ks[4], img)
    img = solarize(ks[5], img)
    return img


@functools.partial(jax.jit, static_argnames=())
def two_views(key, images):
    """images: (B, H, W, 3) -> (x1, x2) augmented views (Algorithm 2 line 6)."""
    B = images.shape[0]
    k1, k2 = jax.random.split(key)
    v1 = jax.vmap(augment_one)(jax.random.split(k1, B), images)
    v2 = jax.vmap(augment_one)(jax.random.split(k2, B), images)
    return v1, v2
