from repro.checkpoint.npz import load_pytree, save_pytree  # noqa: F401
from repro.checkpoint.fl_state import load_fl_state, save_fl_state  # noqa: F401
