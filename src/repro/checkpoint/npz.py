"""Pytree <-> .npz checkpointing (flat key paths, lossless dtypes)."""
from __future__ import annotations

import io
import pathlib

import numpy as np
import jax
import jax.numpy as jnp

SEP = "/"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path, tree):
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    buf = io.BytesIO()
    np.savez(buf, **_flatten(tree))
    path.write_bytes(buf.getvalue())


def load_pytree(path, like):
    """Restore into the structure of ``like`` (same treedef/shapes)."""
    with np.load(pathlib.Path(path), allow_pickle=False) as data:
        flat = dict(data)
    paths_like = jax.tree_util.tree_flatten_with_path(like)[0]
    leaves = []
    for path, leaf in paths_like:
        key = SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
