"""Round-resumable FL training state (global model + round counter)."""
from __future__ import annotations

import json
import pathlib

from repro.checkpoint.npz import load_pytree, save_pytree


def save_fl_state(dirpath, state, round_idx: int, meta: dict | None = None):
    d = pathlib.Path(dirpath)
    d.mkdir(parents=True, exist_ok=True)
    save_pytree(d / "global_state.npz", state)
    (d / "meta.json").write_text(json.dumps(
        {"round": round_idx, **(meta or {})}))


def load_fl_state(dirpath, like):
    d = pathlib.Path(dirpath)
    meta = json.loads((d / "meta.json").read_text())
    state = load_pytree(d / "global_state.npz", like)
    return state, meta["round"], meta
