"""Logical-axis sharding rules → PartitionSpec (MaxText-style).

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
  fsdp = ("pod", "data")   — parameter / batch sharding (ZeRO-3 style)
  tp   = ("model",)        — tensor / expert parallel

Every rule is a tuple of tokens for a leaf's *trailing* dims (leading
stage-stack dims are replicated): token "fsdp" / "tp" / "all" / None.
Tokens degrade gracefully: an axis is only used when the dim is evenly
divisible by it (JAX rejects uneven named sharding), otherwise the next
smaller axis group — or replication — is chosen. This keeps one rule table
valid across all 10 assigned architectures (e.g. kv-head dims smaller than
the model axis simply stay replicated).

Weight-matrix orientation follows Megatron: column-parallel for
d_model→wide projections ("fsdp", "tp"), row-parallel for wide→d_model
("tp", "fsdp"); MoE expert stacks are expert-parallel on "model" with FSDP
on d_model; KV caches shard batch over fsdp and sequence over "model"
(context-parallel decode — for global_batch=1 long-context decode the
sequence dim shards over *all* axes).
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _axes(mesh):
    names = mesh.axis_names
    fsdp = tuple(n for n in ("pod", "data") if n in names)
    return fsdp, ("model",) if "model" in names else ()


def _resolve(token, dim_size, mesh, used=()):
    """Token -> mesh-axis entry for one dim, honoring divisibility and
    skipping axes already used elsewhere in the same PartitionSpec."""
    if token is None:
        return None
    fsdp, tp = _axes(mesh)
    groups = {"fsdp": fsdp, "tp": tp, "all": fsdp + tp}[token]
    groups = tuple(a for a in groups if a not in used)
    # try the full group, then suffixes (drop the biggest axes first)
    for i in range(len(groups)):
        sub = groups[i:]
        if not sub:
            break
        prod = int(np.prod([mesh.shape[a] for a in sub]))
        if prod > 1 and dim_size % prod == 0:
            return sub if len(sub) > 1 else sub[0]
    return None


def _spec_from_rule(rule, shape, mesh):
    n_lead = len(shape) - len(rule)
    entries = [None] * n_lead + [
        _resolve(tok, shape[n_lead + i], mesh) for i, tok in enumerate(rule)]
    return P(*entries)


# rules keyed by leaf name (trailing-dims tokens)
PARAM_RULES = {
    # attention projections
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    # MLP (dense & shared experts & mLSTM up/down)
    "w_up": ("fsdp", "tp"), "w_gate": ("fsdp", "tp"), "w_down": ("tp", "fsdp"),
    # embeddings / output head
    "embed": ("tp", "fsdp"), "lm_head": ("fsdp", "tp"),
    # mamba2
    "w_in": ("fsdp", "tp"), "w_out": ("tp", "fsdp"),
    "conv_w": (None, "tp"), "conv_b": ("tp",),
    "a_log": (None,), "dt_bias": (None,), "D": (None,),
    # MLA
    "w_dkv": ("fsdp", None), "w_kr": ("fsdp", None),
    "w_dq": ("fsdp", None), "w_uq": (None, "tp"),
    "w_uk": ("tp", None, None), "w_uv": ("tp", None, None),
    "w_q": ("fsdp", "tp"),
    # xLSTM (w_q shared with MLA; w_k/w_v are the (di, di) projections)
    "w_k": ("fsdp", "tp"), "w_v": ("fsdp", "tp"),
    "r": (None, "fsdp", "tp"), "w_i": ("fsdp", None), "w_f": ("fsdp", None),
    "b_i": (None,), "b_f": (None,), "w": ("fsdp", "tp"), "b": (None,),
    # MoE router
    "router": ("fsdp", None),
    # ViT stem
    "patch": (None, "fsdp"), "pos": (None, None), "cls": (None, None, None),
    # norms / biases
    "scale": (None,), "bias": (None,),
}

# expert-stacked MoE weights (under a "moe" parent, excluding "shared")
MOE_EXPERT_RULES = {
    "w_gate": ("tp", "fsdp", None),
    "w_up": ("tp", "fsdp", None),
    "w_down": ("tp", None, "fsdp"),
}


def _path_keys(path):
    # same stringification as repro.federated.leaves.path_keys; kept local
    # so the low-level sharding module never imports the federated package
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def param_pspec(path, leaf, mesh) -> P:
    keys = _path_keys(path)
    name = keys[-1]
    if "moe" in keys and "shared" not in keys and name in MOE_EXPERT_RULES:
        rule = MOE_EXPERT_RULES[name]
    elif name in PARAM_RULES:
        rule = PARAM_RULES[name]
    else:
        return P()          # replicate unknown leaves
    if len(rule) > len(leaf.shape):
        return P()
    return _spec_from_rule(rule, leaf.shape, mesh)


def param_pspecs(params, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda p, a: param_pspec(p, a, mesh), params)


def tree_shardings(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# optimizer state: moments shard like their parameters
# ---------------------------------------------------------------------------
def opt_state_specs(opt_state_shapes, param_specs, optimizer: str, mesh):
    """opt_state_shapes: eval_shape of opt.init(params)."""
    if optimizer in ("adamw", "sgdm"):
        def like(tree):
            return jax.tree.map(lambda _, s: s, tree, param_specs)
        out = {}
        for k, v in opt_state_shapes.items():
            if k == "count":
                out[k] = P()
            elif k in ("mu", "nu", "v"):
                out[k] = like(v)
            else:
                out[k] = jax.tree.map(lambda _: P(), v)
        return out
    if optimizer == "adafactor":
        flat_p, tdef = jax.tree.flatten(param_specs,
                                        is_leaf=lambda x: isinstance(x, P))
        flat_m = tdef.flatten_up_to(opt_state_shapes["m"])

        def leaf(spec, st):
            if "vr" in st:
                ent = list(spec) + [None] * (len(st["vr"].shape) + 1
                                             - len(spec))
                return {"vr": P(*ent[:-1]),
                        "vc": P(*(ent[:-2] + [ent[-1]]))}
            return {"v": spec}

        m = tdef.unflatten([leaf(s, st) for s, st in zip(flat_p, flat_m)])
        return {"m": m, "count": P()}
    raise ValueError(optimizer)


# ---------------------------------------------------------------------------
# serving caches / recurrent states
# ---------------------------------------------------------------------------
CACHE_BATCH_POS = {   # name -> batch dim position from the END of the shape
    "k": 4, "v": 4,                 # (..., B, W, Hkv, hd)
    "c_kv": 3, "k_rope": 3,         # (..., B, W, rank)
    "h": 4,                         # (..., B, H, P, N)
    "conv": 3,                      # (..., B, K-1, C)
    "C": 4,                         # (..., B, H, P, P)   mLSTM matrix memory
    "n": 3,                         # (..., B, H, P)
    "m": 2,                         # (..., B, H)
    "c": 2,                         # (..., B, d)         sLSTM
}
# per-name rule for the dims after the batch dim
CACHE_TAIL_RULES = {
    "k": ("seq", "tp", None), "v": ("seq", "tp", None),
    "c_kv": ("seq", None), "k_rope": ("seq", None),
    "h": ("tp", None, None), "conv": (None, "tp"),
    "C": (None, "tp", None), "n": (None, "tp"), "m": (None,),
    "c": ("tp",),
}


def cache_pspec(path, leaf, mesh, batch: int):
    keys = _path_keys(path)
    name = keys[-1]
    if name == "pos":
        return P()
    if "slstm" in keys:
        # sLSTM state leaves are all (..., B, d) regardless of name
        bpos, tail = len(leaf.shape) - 2, ("tp",)
    elif name in CACHE_BATCH_POS:
        bpos = len(leaf.shape) - CACHE_BATCH_POS[name]
        tail = CACHE_TAIL_RULES[name]
    else:
        return P()
    fsdp, _ = _axes(mesh)
    fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp])) if fsdp else 1
    batch_shardable = fsdp_size > 1 and batch % fsdp_size == 0
    entries = [None] * len(leaf.shape)
    used = set()

    def mark(entry):
        if entry is None:
            return entry
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            used.add(a)
        return entry

    if batch_shardable:
        entries[bpos] = mark(fsdp if len(fsdp) > 1 else fsdp[0])
    for i, tok in enumerate(tail):
        dim = bpos + 1 + i
        if dim >= len(leaf.shape) or tok is None:
            continue
        if tok == "seq":
            # context parallel: over "model"; over everything when the
            # batch could not be sharded (global_batch=1 long decode)
            tok2 = "tp" if batch_shardable else "all"
            entries[dim] = mark(_resolve(tok2, leaf.shape[dim], mesh,
                                         used=tuple(used)))
        else:
            entries[dim] = mark(_resolve(tok, leaf.shape[dim], mesh,
                                         used=tuple(used)))
    return P(*entries)


def cache_pspecs(caches, mesh, batch: int):
    return jax.tree_util.tree_map_with_path(
        lambda p, a: cache_pspec(p, a, mesh, batch), caches)


# ---------------------------------------------------------------------------
# batch inputs
# ---------------------------------------------------------------------------
def batch_specs(batch_tree, mesh):
    """Shard dim 0 (global batch) over fsdp axes when divisible."""
    def leaf(a):
        if a.ndim == 0:
            return P()
        ent = _resolve("fsdp", a.shape[0], mesh)
        return P(*([ent] + [None] * (a.ndim - 1)))
    return jax.tree.map(leaf, batch_tree)
