from repro.sharding.rules import (  # noqa: F401
    batch_specs, cache_pspecs, opt_state_specs, param_pspecs, tree_shardings)
