"""MoCo v3 MLP heads (paper Tables B.7 / B.8).

Projection head H: 3-layer MLP, hidden 4096, out 256, BN + ReLU after
hidden layers, BN (no affine-relu) on the output layer.
Prediction head P: 2-layer MLP, hidden 4096, out 256.

BatchNorm uses in-batch statistics inside the jit'd step (sync-BN within a
client's local batch), matching the MoCo v3 recipe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.init import dense_init
from repro.models.layers.norms import batchnorm, batchnorm_init


def _mlp_head_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    layers = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        layers.append({"w": dense_init(ks[i], (a, b), dtype),
                       "bn": batchnorm_init(b, dtype)})
    return {"layers": layers}


def proj_init(key, d_in: int, hidden: int, out: int, dtype=jnp.float32):
    return _mlp_head_init(key, (d_in, hidden, hidden, out), dtype)


def pred_init(key, d_in: int, hidden: int, out: int, dtype=jnp.float32):
    return _mlp_head_init(key, (d_in, hidden, out), dtype)


def head_apply(params, x, eps: float = 1e-5):
    """x: (B, d_in) -> (B, d_out). ReLU on all but the last layer."""
    n = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        x = x.astype(jnp.float32) @ layer["w"].astype(jnp.float32)
        x = batchnorm(layer["bn"], x, eps)
        if i < n - 1:
            x = jax.nn.relu(x)
    return x
