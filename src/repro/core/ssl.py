"""Self-supervised learning engines: MoCo v3 (paper default), SimCLR, BYOL.

State layout (a pytree, usable directly under pjit):

    {"online": {"enc": F, "proj": H, "pred": P},
     "target": {"enc": F_k, "proj": H_k}}

The encoder is abstracted behind an ``Encoder`` record so the same SSL code
drives the paper's ViT-Tiny on images and the assigned LM architectures on
token sequences (representation = mean-pooled final hidden state).

MoCo v3 local loss with representation alignment is Algorithm 2 of the
paper; ``momentum_update`` is the target-branch EMA; the server-side
calibration step (Algorithm 1, line 7) reuses ``ssl_loss`` with
``active_from=0`` — end-to-end over the current sub-model.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import heads, losses
from repro.models import lm as lm_mod
from repro.models import vit as vit_mod


# ---------------------------------------------------------------------------
# Encoder abstraction
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Encoder:
    init: Callable[..., Any]            # (key) -> params
    apply: Callable[..., Any]           # (params, x, sub_layers, active_from,
    #                                      layer_gates) -> (B, d_repr)
    d_repr: int
    num_stages: int


def make_vit_encoder(cfg, image_size: int = 32, patch_size: int = 4) -> Encoder:
    def init(key):
        return vit_mod.init_vit(key, cfg, image_size, patch_size)

    def apply(params, x, sub_layers=None, active_from=0, layer_gates=None):
        return vit_mod.vit_forward(params, x, cfg, patch_size=patch_size,
                                   sub_layers=sub_layers,
                                   active_from=active_from,
                                   layer_gates=layer_gates)

    return Encoder(init, apply, cfg.d_model, cfg.num_layers)


def make_lm_encoder(cfg) -> Encoder:
    """Token encoder: mean-pooled final hidden state of the (sub-)model."""
    def init(key):
        return lm_mod.init_lm(key, cfg)

    def apply(params, tokens, sub_layers=None, active_from=0, layer_gates=None):
        x = lm_mod.embed(params, tokens, cfg)
        h, _ = lm_mod.forward_hidden(params, x, cfg, sub_layers=sub_layers,
                                     active_from=active_from)
        return jnp.mean(h.astype(jnp.float32), axis=1)

    return Encoder(init, apply, cfg.d_model, lm_mod.num_stages(cfg))


# ---------------------------------------------------------------------------
# init / EMA
# ---------------------------------------------------------------------------
def ssl_init(key, encoder: Encoder, ssl_cfg, dtype=jnp.float32):
    ke, kp, kq = jax.random.split(key, 3)
    enc = encoder.init(ke)
    proj = heads.proj_init(kp, encoder.d_repr, ssl_cfg.proj_hidden,
                           ssl_cfg.proj_dim, dtype)
    online = {"enc": enc, "proj": proj}
    if ssl_cfg.method in ("moco_v3", "byol"):
        online["pred"] = heads.pred_init(kq, ssl_cfg.proj_dim,
                                         ssl_cfg.pred_hidden,
                                         ssl_cfg.proj_dim, dtype)
    state = {"online": online}
    if ssl_cfg.method in ("moco_v3", "byol"):
        state["target"] = {"enc": jax.tree.map(jnp.copy, enc),
                           "proj": jax.tree.map(jnp.copy, proj)}
    return state


def momentum_update(state, mu: float):
    """target <- mu * target + (1 - mu) * online  (Algorithm 2, line 15)."""
    if "target" not in state:
        return state
    new_t = jax.tree.map(
        lambda t, o: mu * t + (1.0 - mu) * o.astype(t.dtype),
        state["target"],
        {"enc": state["online"]["enc"], "proj": state["online"]["proj"]})
    return {**state, "target": new_t}


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def _branch(enc_params, head_params, pred_params, x, encoder: Encoder,
            sub_layers, active_from, layer_gates=None):
    z = encoder.apply(enc_params, x, sub_layers, active_from, layer_gates)
    p = heads.head_apply(head_params, z)
    if pred_params is not None:
        p = heads.head_apply(pred_params, p)
    return z, p


def ssl_loss(state, x1, x2, encoder: Encoder, ssl_cfg, *,
             sub_layers: Optional[int] = None, active_from: int = 0,
             layer_gates=None, global_enc=None, align_weight: float = 0.0):
    """Local SSL loss for a pair of augmented views (Algorithm 2, lines 6-13).

    Returns (loss, metrics). ``global_enc`` (the broadcast global encoder) is
    only needed when ``align_weight > 0`` — representation alignment, Eq. 3.
    """
    o = state["online"]
    method = ssl_cfg.method
    tau = ssl_cfg.temperature

    if method == "moco_v3":
        z1, q1 = _branch(o["enc"], o["proj"], o["pred"], x1, encoder,
                         sub_layers, active_from, layer_gates)
        z2, q2 = _branch(o["enc"], o["proj"], o["pred"], x2, encoder,
                         sub_layers, active_from, layer_gates)
        t = state["target"]
        _, k1 = _branch(t["enc"], t["proj"], None, x1, encoder,
                        sub_layers, sub_layers or encoder.num_stages)
        _, k2 = _branch(t["enc"], t["proj"], None, x2, encoder,
                        sub_layers, sub_layers or encoder.num_stages)
        loss = losses.moco_contrastive(q1, k2, q2, k1, tau)
    elif method == "simclr":
        z1, p1 = _branch(o["enc"], o["proj"], None, x1, encoder,
                         sub_layers, active_from, layer_gates)
        z2, p2 = _branch(o["enc"], o["proj"], None, x2, encoder,
                         sub_layers, active_from, layer_gates)
        loss = losses.simclr_nt_xent(p1, p2, tau)
    elif method == "byol":
        z1, q1 = _branch(o["enc"], o["proj"], o["pred"], x1, encoder,
                         sub_layers, active_from, layer_gates)
        z2, q2 = _branch(o["enc"], o["proj"], o["pred"], x2, encoder,
                         sub_layers, active_from, layer_gates)
        t = state["target"]
        _, k1 = _branch(t["enc"], t["proj"], None, x1, encoder,
                        sub_layers, sub_layers or encoder.num_stages)
        _, k2 = _branch(t["enc"], t["proj"], None, x2, encoder,
                        sub_layers, sub_layers or encoder.num_stages)
        loss = losses.byol_regression(q1, k2) + losses.byol_regression(q2, k1)
    else:
        raise ValueError(method)

    metrics = {"con": loss}
    if align_weight > 0.0:
        assert global_enc is not None, "alignment needs the global encoder"
        zg1 = encoder.apply(global_enc, x1, sub_layers, 0)
        zg2 = encoder.apply(global_enc, x2, sub_layers, 0)
        la = losses.align_loss(z1, zg2, z2, zg1, tau)
        loss = loss + align_weight * la
        metrics["align"] = la
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# LM-family SSL: next-token prediction + representation alignment
# ---------------------------------------------------------------------------
def lm_ssl_loss(params, batch, cfg, *, sub_layers=None, active_from: int = 0,
                global_params=None, align_weight: float = 0.0,
                tau: float = 0.2, remat: bool = False):
    """Self-supervised loss for assigned LM architectures.

    Next-token cross-entropy (the LM-native SSL objective) over the stage-s
    sub-model, plus the paper's Eq. 3 alignment between local and global
    mean-pooled hidden states when ``align_weight > 0``.
    """
    x = lm_mod.embed(params, batch["tokens"], cfg, batch.get("frontend"))
    hidden, aux = lm_mod.forward_hidden(params, x, cfg, sub_layers=sub_layers,
                                        active_from=active_from, remat=remat)
    P = 0 if batch.get("frontend") is None else batch["frontend"].shape[1]
    h_tok = hidden[:, P:] if P else hidden
    xent = lm_mod.xent_loss(params, h_tok, batch["labels"], cfg,
                            batch.get("mask"))
    loss = xent + aux
    metrics = {"xent": xent, "aux": aux}
    if align_weight > 0.0 and global_params is not None:
        z_local = jnp.mean(hidden.astype(jnp.float32), axis=1)
        xg = lm_mod.embed(global_params, batch["tokens"], cfg,
                          batch.get("frontend"))
        hg, _ = lm_mod.forward_hidden(global_params, xg, cfg,
                                      sub_layers=sub_layers, active_from=0)
        z_global = jax.lax.stop_gradient(
            jnp.mean(hg.astype(jnp.float32), axis=1))
        la = losses.info_nce(z_local, z_global, tau)
        loss = loss + align_weight * la
        metrics["align"] = la
    metrics["loss"] = loss
    return loss, metrics
