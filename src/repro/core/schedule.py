"""Stage schedules for layer-wise / progressive federated training.

Builds a per-round plan for the five training modes of the paper:

  e2e          FedMoCo / FedBYOL / FedSimCLR: full model every round.
  layerwise    FedMoCo-LW: stage s trains only L_s, exchanges only L_s.
  lw_fedssl    LW-FedSSL: layerwise + server-side calibration (download is
               L_1..L_s because the server updates every layer) +
               representation alignment in the local loss.
  progressive  Prog-FedSSL: stage s trains and exchanges L_1..L_s.
  fll_dd       FLL + depth dropout: layerwise, frozen layers dropped with
               probability ``depth_dropout`` during local training.

Round allocation across stages (paper Section 5.10): ``uniform``,
``right_skewed`` (more rounds to earlier stages) and ``left_skewed``
(more rounds to later stages); total is always ``fl.rounds``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class RoundPlan:
    round_idx: int          # 0-based global communication round
    stage: int              # 1-based stage s
    sub_layers: int         # depth of the stage-s sub-model, in stages
    active_from: int        # stages < active_from are frozen in local training
    new_stage: bool         # first round of its stage (append layer / transfer)
    download_stages: Tuple[int, int]   # [lo, hi) stage range client downloads
    upload_stages: Tuple[int, int]     # [lo, hi) stage range client uploads
    server_calibrate: bool  # run server-side SSL on D_g after aggregation
    align: bool             # add representation-alignment loss locally
    depth_dropout: float    # frozen-layer drop probability (FLL+DD)


SCHEDULES = ("e2e", "layerwise", "lw_fedssl", "progressive", "fll_dd")


def stage_rounds(total_rounds: int, num_stages: int, allocation: str
                 ) -> List[int]:
    """Number of rounds per stage; sums exactly to ``total_rounds``."""
    S = num_stages
    if total_rounds < S:
        raise ValueError(
            f"need at least one round per stage: rounds={total_rounds} < "
            f"stages={S}")
    if allocation == "uniform":
        w = [1.0] * S
    elif allocation == "right_skewed":    # more rounds early
        w = [float(S - s) for s in range(S)]
    elif allocation == "left_skewed":     # more rounds late
        w = [float(s + 1) for s in range(S)]
    else:
        raise ValueError(allocation)
    tot = sum(w)
    out = [max(1, int(total_rounds * x / tot)) for x in w]
    # fix rounding drift, preserving the skew direction
    i = 0
    while sum(out) < total_rounds:
        out[i % S] += 1
        i += 1
    while sum(out) > total_rounds:
        j = max((s for s in range(S) if out[s] > 1),
                key=lambda s: out[s])
        out[j] -= 1
    return out


def build_schedule(fl, num_stages: int) -> List[RoundPlan]:
    """fl: FLConfig. Returns one RoundPlan per communication round."""
    mode = fl.schedule
    if mode not in SCHEDULES:
        raise ValueError(f"unknown schedule '{mode}'; one of {SCHEDULES}")
    R, S = fl.rounds, num_stages
    plans: List[RoundPlan] = []
    if mode == "e2e":
        for r in range(R):
            plans.append(RoundPlan(
                round_idx=r, stage=S, sub_layers=S, active_from=0,
                new_stage=False, download_stages=(0, S), upload_stages=(0, S),
                server_calibrate=False, align=False, depth_dropout=0.0))
        return plans

    per_stage = (list(fl.rounds_per_stage) if fl.rounds_per_stage
                 else stage_rounds(R, S, fl.stage_allocation))
    assert len(per_stage) == S and sum(per_stage) == R, (per_stage, R)
    r = 0
    for s in range(1, S + 1):
        for j in range(per_stage[s - 1]):
            new = j == 0
            if mode == "layerwise":
                plans.append(RoundPlan(r, s, s, s - 1, new,
                                       (s - 1, s), (s - 1, s),
                                       False, False, 0.0))
            elif mode == "fll_dd":
                plans.append(RoundPlan(r, s, s, s - 1, new,
                                       (s - 1, s), (s - 1, s),
                                       False, False, fl.depth_dropout))
            elif mode == "lw_fedssl":
                plans.append(RoundPlan(r, s, s, s - 1, new,
                                       (0, s), (s - 1, s),
                                       True, True, 0.0))
            elif mode == "progressive":
                plans.append(RoundPlan(r, s, s, 0, new,
                                       (0, s), (0, s),
                                       False, False, 0.0))
            r += 1
    return plans


# ---------------------------------------------------------------------------
# weight transfer (paper Appendix B.2): init L_s from L_{s-1} at stage start
# ---------------------------------------------------------------------------
def weight_transfer(stacked_params, stage: int):
    """Copy block ``stage-2`` into block ``stage-1`` (0-based stack index).

    ``stacked_params`` is any pytree whose leaves are stacked over the stage
    axis (leading dim). No-op for stage 1.
    """
    if stage < 2:
        return stacked_params
    src, dst = stage - 2, stage - 1
    return jax.tree.map(lambda a: a.at[dst].set(a[src]), stacked_params)


def transfer_model(params, cfg, stage: int):
    """Apply weight transfer to a model params dict (uniform/zamba/xlstm)."""
    params = dict(params)
    for key in ("blocks", "mlstm", "slstm"):
        if key in params:
            params[key] = weight_transfer(params[key], stage)
    if "enc_blocks" in params:
        params["enc_blocks"] = weight_transfer(params["enc_blocks"], stage)
    return params


# ---------------------------------------------------------------------------
# depth dropout (FLL+DD): gates over frozen stages
# ---------------------------------------------------------------------------
def depth_dropout_gates(key, num_stages: int, active_from: int, rate: float):
    """(S,) float gates: active/unbuilt stages always 1, frozen stages kept
    with prob 1-rate. Gate multiplies the block's residual delta."""
    keep = (jax.random.uniform(key, (num_stages,)) >= rate).astype(jnp.float32)
    idx = jnp.arange(num_stages)
    return jnp.where(idx >= active_from, 1.0, keep)
