"""Contrastive losses: InfoNCE (paper Eq. 2) and representation alignment
(paper Eq. 3).

Both operate on (B, d) vectors with in-batch negatives: for row i the
positive is row i of the other view and rows j != i are negatives. Logits
and softmax are computed in fp32 for numerical robustness; the B x B logits
matrix is the SSL compute hot-spot covered by the fused Pallas kernel in
``repro.kernels.infonce`` (validated against this oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l2_normalize(x, eps: float = 1e-12):
    xf = x.astype(jnp.float32)
    return xf / jnp.maximum(jnp.linalg.norm(xf, axis=-1, keepdims=True), eps)


def info_nce(q, k, tau: float):
    """InfoNCE with in-batch negatives (Eq. 2).

    q: (B, d) online vectors; k: (B, d) target vectors (stop-gradient is the
    caller's responsibility). Returns scalar mean loss.
    """
    q = l2_normalize(q)
    k = l2_normalize(k)
    logits = (q @ k.T) / tau                      # (B, B) fp32
    labels = jnp.arange(q.shape[0])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def moco_contrastive(q1, k2, q2, k1, tau: float):
    """Symmetrized MoCo v3 loss: l(q1,k2) + l(q2,k1)  (Algorithm 2, line 11).

    MoCo v3 scales the loss by 2*tau; we keep the plain sum, which only
    rescales the effective learning rate.
    """
    return info_nce(q1, jax.lax.stop_gradient(k2), tau) + \
        info_nce(q2, jax.lax.stop_gradient(k1), tau)


def align_loss(z1_local, z2_global, z2_local, z1_global, tau: float):
    """Representation alignment (Eq. 3), symmetrized (Algorithm 2, line 12):

        l(z1_i, z2) + l(z2_i, z1)

    where z*_local come from the local encoder F_i and z*_global from the
    frozen global encoder F. Negatives are other samples' global reps.
    """
    return info_nce(z1_local, jax.lax.stop_gradient(z2_global), tau) + \
        info_nce(z2_local, jax.lax.stop_gradient(z1_global), tau)


def byol_regression(q, k):
    """BYOL: negative cosine similarity (2 - 2*cos once normalized)."""
    q = l2_normalize(q)
    k = l2_normalize(k)
    return jnp.mean(jnp.sum((q - jax.lax.stop_gradient(k)) ** 2, axis=-1))


def simclr_nt_xent(z1, z2, tau: float):
    """NT-Xent over 2B views: positives are (i, i+B); negatives all others."""
    B = z1.shape[0]
    z = l2_normalize(jnp.concatenate([z1, z2], axis=0))    # (2B, d)
    logits = (z @ z.T) / tau
    logits = logits - 1e9 * jnp.eye(2 * B)                 # mask self
    labels = jnp.concatenate([jnp.arange(B) + B, jnp.arange(B)])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)
