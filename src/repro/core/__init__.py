# The paper's primary contribution: layer-wise federated SSL.
#   losses    — InfoNCE (Eq. 2), representation alignment (Eq. 3), NT-Xent, BYOL
#   heads     — MoCo v3 projection/prediction MLP heads
#   ssl       — MoCo v3 / SimCLR / BYOL engines over an Encoder abstraction
#   schedule  — e2e / layerwise / lw_fedssl / progressive / fll_dd round plans,
#               weight transfer, depth dropout
from repro.core import heads, losses, schedule, ssl  # noqa: F401
