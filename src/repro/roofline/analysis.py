"""Three-term roofline analysis from a compiled XLA artifact.

  compute term    = HLO_FLOPs_per_device   / peak_FLOP/s     (197 TF bf16)
  memory term     = HLO_bytes_per_device   / HBM_bw          (819 GB/s)
  collective term = collective_bytes_per_device / link_bw    (~50 GB/s)

``compiled.cost_analysis()`` is evaluated on the SPMD-partitioned
per-device module, so its flops/bytes are already per-device. Collective
bytes are NOT in cost_analysis: we parse the optimized (post-partitioning)
HLO text and sum the *result* shapes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (a standard first-order
traffic estimate; ring-algorithm constants fold into the ~50 GB/s
effective link bandwidth).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# e.g.  "  %ag = bf16[2,1024,128]{2,1,0} all-gather(...)"
_SHAPE_RE = re.compile(
    r"(?:\(|\s|^)(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
    r"(" + "|".join(COLLECTIVE_OPS) + r")[-a-z]*\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum collective result bytes per op kind from optimized HLO text."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    count = {k: 0 for k in COLLECTIVE_OPS}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(shape_str)
        count[op] += 1
    return {"bytes": out, "counts": count,
            "total": sum(out.values())}


def model_flops(cfg, shape, mode: str) -> float:
    """Useful-work floor: 6·N_active·D train, 2·N_active·D forward-only."""
    n = cfg.active_param_count()
    if mode in ("train", "train_lw"):
        tokens = shape.global_batch * shape.seq_len
        f = 6.0 * n * tokens
        if mode == "train_lw":
            # full forward + (1/S) backward + alignment forward (global model)
            S = max(1, cfg.num_layers)
            f = 2.0 * n * tokens * (1 + 1) + 4.0 * n * tokens / S
        return f
    if mode == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    if mode == "decode":
        return 2.0 * n * shape.global_batch
    raise ValueError(mode)


def chunk_loop_correction(cfg, shape, mode: str, n_devices: int) -> float:
    """Per-device FLOPs that rolled chunk/time loops hide from
    cost_analysis (see repro.models.scan_cfg.CHUNK_UNROLL).

    SSD intra-chunk terms per layer per sequence (fwd):
        2*S*Q*N  (C·B)  +  2*S*Q*H*P  (mask·x)  +  4*S*N*H*P  (state I/O)
    mLSTM chunked core:  4*S*Q*d_inner + 4*S*d_inner*P
    sLSTM recurrence:    S * 8 * d * P_head
    Train multiplies by 3 (fwd + 2x bwd); decode steps have no chunk loops.
    """
    if mode == "decode":
        return 0.0
    mult = 3.0 if mode in ("train", "train_lw") else 1.0
    B, S = shape.global_batch, shape.seq_len
    extra = 0.0
    if cfg.ssm is not None and cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        Q = min(s.chunk_size, S)
        d_in = s.expand * cfg.d_model
        H = d_in // s.head_dim
        N, P = s.state_dim, s.head_dim
        per_seq = 2 * S * Q * N + 2 * S * Q * H * P + 4 * S * N * H * P
        extra += cfg.num_layers * B * per_seq * mult
    if cfg.xlstm is not None:
        from repro.models.layers.xlstm import MLSTM_CHUNK
        d_in = int(cfg.xlstm.proj_factor * cfg.d_model)
        P = d_in // cfg.num_heads
        Q = min(MLSTM_CHUNK, S)
        per = cfg.xlstm.slstm_every or cfg.num_layers
        n_mlstm = cfg.num_layers - cfg.num_layers // per
        n_slstm = cfg.num_layers // per
        extra += n_mlstm * B * (4 * S * Q * d_in + 4 * S * d_in * P) * mult
        d = cfg.d_model
        extra += n_slstm * B * S * 8 * d * (d // cfg.num_heads) * mult
    return extra / n_devices


@dataclass
class RooflineResult:
    arch: str
    shape: str
    mode: str
    mesh: str
    n_devices: int
    flops_dev: float
    bytes_dev: float
    coll_bytes_dev: float
    coll_detail: dict
    mem_per_device: dict
    model_flops_total: float

    @property
    def compute_s(self):
        return self.flops_dev / PEAK_FLOPS_BF16

    @property
    def memory_s(self):
        return self.bytes_dev / HBM_BW

    @property
    def collective_s(self):
        return self.coll_bytes_dev / ICI_BW

    @property
    def dominant(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self):
        hlo_total = self.flops_dev * self.n_devices
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    def to_dict(self):
        return {
            "arch": self.arch, "shape": self.shape, "mode": self.mode,
            "mesh": self.mesh, "n_devices": self.n_devices,
            "flops_dev": self.flops_dev, "bytes_dev": self.bytes_dev,
            "coll_bytes_dev": self.coll_bytes_dev,
            "coll_detail": self.coll_detail,
            "mem_per_device": self.mem_per_device,
            "model_flops_total": self.model_flops_total,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
        }


def cost_dict(stage) -> dict:
    """``cost_analysis()`` of a ``Lowered`` or ``Compiled`` stage as one
    flat dict (older jax returns ``[dict]``)."""
    cost = stage.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return dict(cost or {})


def memory_dict(compiled) -> dict:
    """``memory_analysis()`` of a ``Compiled`` as argument/output/temp/
    peak bytes. ``peak_memory_in_bytes`` is backend-dependent (absent or
    None on CPU) — the fallback is the buffer-assignment sum, which upper-
    bounds the live set the same way the analytic model does."""
    mem = compiled.memory_analysis()
    return {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0) or (
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)),
    }


def analyze_compiled(compiled, *, arch, shape, mode, mesh_name, n_devices,
                     cfg, shape_cfg, cost_scale: float = 1.0
                     ) -> RooflineResult:
    """cost_scale corrects for rolled loops XLA counts once (the gradient-
    accumulation scan: body = one full fwd+bwd, trip count = microbatch)."""
    cost = cost_dict(compiled)
    mem_d = memory_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    from repro.models import scan_cfg
    extra = 0.0
    if not scan_cfg.CHUNK_UNROLL and scan_cfg.UNROLL:
        extra = chunk_loop_correction(cfg, shape_cfg, mode, n_devices)
    return RooflineResult(
        arch=arch, shape=shape, mode=mode, mesh=mesh_name,
        n_devices=n_devices,
        flops_dev=float(cost.get("flops", 0.0)) * cost_scale + extra,
        bytes_dev=float(cost.get("bytes accessed", 0.0)) * cost_scale,
        coll_bytes_dev=float(coll["total"]) * cost_scale,
        coll_detail=coll,
        mem_per_device=mem_d,
        model_flops_total=model_flops(cfg, shape_cfg, mode),
    )


def roofline_report(res: RooflineResult) -> str:
    t = res.to_dict()
    return (
        f"{res.arch:28s} {res.shape:12s} {res.mode:9s} {res.mesh:9s} "
        f"comp {t['compute_s']*1e3:9.3f}ms  mem {t['memory_s']*1e3:9.3f}ms  "
        f"coll {t['collective_s']*1e3:9.3f}ms  -> {t['dominant']:10s} "
        f"useful {t['useful_ratio']*100:5.1f}%  "
        f"args {t['mem_per_device']['argument_bytes']/2**30:6.2f}GiB "
        f"peak {t['mem_per_device']['peak_bytes']/2**30:6.2f}GiB")
