from repro.roofline.analysis import (  # noqa: F401
    analyze_compiled, collective_bytes, model_flops, roofline_report)
