"""Analytical per-client resource model (paper's accounting, Appendix A.1).

FLOPs: forward FLOPs per single input sample (fvcore-style dense counts);
backward = 2x forward of the *trainable* portion (2:1 ratio, refs [44-47]).
Memory: parameters + optimizer moments of the trainable portion +
activation footprint of layers that participate in backward (+ a single
transient layer buffer for the frozen forward prefix).
Communication: byte counts of the actual parameter pytrees sliced by the
round plan (repro.federated.comm).

All quantities are computed from the ViT config + MoCo v3 head dims, so
Table 1/3 ratios and the Fig. 5/6 curves are structural predictions that we
compare against the paper's measured values in EXPERIMENTS.md — and, since
the resource observatory landed, against the *measured* XLA
``cost_analysis``/``memory_analysis`` numbers of the programs we actually
compile (``repro.obs.resources``, ``python -m repro.launch.trace
--paper-table``).

Historically this lived in ``benchmarks/resources.py``; it moved under
``repro.roofline`` so the trace CLI and the observatory (which run with
only ``src`` on the path) can price the analytic columns next to the
measured ones. ``benchmarks.resources`` re-exports everything.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.configs.base import FLConfig, SSLConfig, load_arch
from repro.core import schedule as sched
from repro.federated import comm

BYTES_F32 = 4

# paper Table 3 cost columns (memory, flops, comm) vs FedMoCo — the
# published full-scale multipliers every measured/analytic table prints
# alongside its own ratios
PAPER_MULT = {"e2e": (1.00, 1.00, 1.00), "layerwise": (0.25, 0.35, 0.08),
              "lw_fedssl": (0.30, 0.48, 0.31),
              "progressive": (1.00, 0.57, 0.54),
              "fll_dd": (0.62, 0.36, 0.08)}
SCHEDULE_NAMES = {"e2e": "FedMoCo", "layerwise": "FedMoCo-LW",
                  "lw_fedssl": "LW-FedSSL", "progressive": "Prog-FedSSL",
                  "fll_dd": "FLL+DD"}


# ---------------------------------------------------------------------------
# per-component forward FLOPs / activation floats (ViT + MoCo v3 heads)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class VitCosts:
    tokens: int
    d: int
    d_ff: int
    heads: int
    layers: int
    proj_hidden: int
    proj_dim: int
    pred_hidden: int

    @property
    def f_stem(self):
        return 2 * self.tokens * 48 * self.d            # patch proj (4x4x3)

    @property
    def f_block(self):
        t, d = self.tokens, self.d
        attn = 2 * t * d * (3 * d) + 2 * t * t * d * 2 + 2 * t * d * d
        mlp = 2 * t * d * self.d_ff * 2
        return attn + mlp

    @property
    def f_proj(self):
        return 2 * (self.d * self.proj_hidden
                    + self.proj_hidden * self.proj_hidden
                    + self.proj_hidden * self.proj_dim)

    @property
    def f_pred(self):
        return 2 * (self.proj_dim * self.pred_hidden
                    + self.pred_hidden * self.proj_dim)

    @property
    def a_block(self):
        """Activation floats per sample per block (residuals, qkv, attn
        matrices, mlp hidden) — what backward must keep."""
        t, d = self.tokens, self.d
        return t * d * (3 + 1 + 2 + 2) + 2 * self.heads * t * t \
            + 2 * t * self.d_ff

    @property
    def a_stem(self):
        return 2 * self.tokens * self.d

    @property
    def a_heads(self):
        return 2 * (self.proj_hidden * 2 + self.proj_dim) \
            + (self.pred_hidden + self.proj_dim)


def vit_costs(cfg=None, ssl=None) -> VitCosts:
    cfg = cfg or load_arch("vit-tiny")
    ssl = ssl or SSLConfig()
    return VitCosts(tokens=65, d=cfg.d_model, d_ff=cfg.d_ff,
                    heads=cfg.num_heads, layers=cfg.num_layers,
                    proj_hidden=ssl.proj_hidden, proj_dim=ssl.proj_dim,
                    pred_hidden=ssl.pred_hidden)


# ---------------------------------------------------------------------------
# per-round client costs by schedule
# ---------------------------------------------------------------------------
def flops_per_sample_round(c: VitCosts, plan) -> float:
    """MoCo v3 local step FLOPs for one sample in one round (2 views)."""
    s, act = plan.sub_layers, plan.active_from
    fwd_frozen = c.f_stem + act * c.f_block
    fwd_active = (s - act) * c.f_block + c.f_proj + c.f_pred
    online = 2 * (fwd_frozen + fwd_active)              # 2 views
    target = 2 * (c.f_stem + s * c.f_block + c.f_proj)  # EMA branch, fwd only
    bwd = 2 * 2 * fwd_active                            # 2:1 ratio, 2 views
    if act > 0:
        bwd += 2 * 2 * 0                                # frozen: no backward
    total = online + target + bwd
    if plan.align:
        total += 2 * (c.f_stem + s * c.f_block)         # global model fwd
    return total


def memory_bytes(c: VitCosts, plan, batch: int,
                 params_bytes_total: int) -> float:
    """Peak local-training memory (paper Fig. 5a / Fig. 6b)."""
    s, act = plan.sub_layers, plan.active_from
    frac_params = (c.f_stem / c.f_block + s) / (c.f_stem / c.f_block
                                                + c.layers)
    p_bytes = params_bytes_total * frac_params
    p_bytes *= 2                                        # online + target
    opt_bytes = 2 * params_bytes_total * (s - act) / c.layers  # AdamW moments
    acts = (c.a_stem + (s - act) * c.a_block + c.a_heads) * batch * BYTES_F32
    acts += c.a_block * batch * BYTES_F32 * (1 if act > 0 else 0)  # transient
    if plan.align:
        acts += c.a_stem * batch * BYTES_F32            # global rep buffers
    return p_bytes + opt_bytes + acts


def build_ssl_param_tree(cfg=None, ssl=None):
    """Abstract (eval_shape) online-state tree for comm accounting."""
    from repro.core import ssl as ssl_mod
    cfg = cfg or load_arch("vit-tiny")
    ssl = ssl or SSLConfig()
    enc = ssl_mod.make_vit_encoder(cfg)
    return jax.eval_shape(
        lambda: ssl_mod.ssl_init(jax.random.PRNGKey(0), enc, ssl))


def schedule_costs(schedule: str, *, rounds: int = 180, batch: int = 1024,
                   local_epochs: int = 3, cfg=None, ssl=None,
                   depth_dropout: float = 0.5,
                   stage_allocation: str = "uniform"):
    """Returns dict with total flops/sample, peak memory, comm bytes and
    the per-round series — everything Table 1/3 + Fig. 5 need."""
    cfg = cfg or load_arch("vit-tiny")
    c = vit_costs(cfg, ssl)
    fl = FLConfig(rounds=rounds, schedule=schedule,
                  depth_dropout=depth_dropout,
                  stage_allocation=stage_allocation)
    plans = sched.build_schedule(fl, cfg.num_layers)
    state = build_ssl_param_tree(cfg, ssl)
    enc_tree = state["online"]["enc"]
    params_bytes_total = comm.tree_bytes(enc_tree)

    flops, mem, down, up = [], [], [], []
    for p in plans:
        f = flops_per_sample_round(c, p) * local_epochs
        if p.depth_dropout > 0:
            # frozen-prefix forward cost drops proportionally
            s, act = p.sub_layers, p.active_from
            saved = p.depth_dropout * act * c.f_block
            f -= (2 + 2) * saved * local_epochs
        flops.append(f)
        mem.append(memory_bytes(c, p, batch, params_bytes_total))
        cb = comm.round_comm_bytes(enc_tree, p, include_heads=False)
        down.append(cb["download"])
        up.append(cb["upload"])
    return {
        "schedule": schedule,
        "flops_total": float(np.sum(flops)),
        "peak_memory": float(np.max(mem)),
        "download_total": int(np.sum(down)),
        "upload_total": int(np.sum(up)),
        "comm_total": int(np.sum(down) + np.sum(up)),
        "series": {"flops": flops, "memory": mem, "download": down,
                   "upload": up,
                   "stage": [p.stage for p in plans]},
    }
