"""InternLM2-20B — dense GQA [arXiv:2403.17297]."""
from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    arch_id="internlm2-20b", family="dense",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92544,
    source="arXiv:2403.17297",
    notes="long_500k uses window=8192",
)
TRAIN = TrainConfig(optimizer="adamw", remat=True, microbatch=4)
