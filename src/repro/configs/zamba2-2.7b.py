"""Zamba2-2.7B — hybrid Mamba2 + shared attention [arXiv:2411.15242].

54 Mamba2 blocks; one *shared* (weight-tied) attention+MLP block applied
every 6 Mamba blocks (attn_every=6 -> 9 stage groups). Layer-wise stage =
one group of 6 Mamba blocks; the shared attention block trains whenever any
stage is active (weight sharing spans depths — DESIGN.md Arch-applicability).
long_500k: native (sub-quadratic SSM; the shared-attn KV cache is context-
parallel sharded).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    attn_every=6,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256),
    source="arXiv:2411.15242",
    notes="shared attention block trained in every stage (weight tying)",
)
