"""SeamlessM4T-medium — encoder-decoder, multimodal [arXiv:2308.11596].

Transformer backbone only: 12-layer bidirectional encoder over precomputed
speech-frame embeddings (the conformer/mel frontend is a STUB per the
carve-out) + 12-layer causal decoder with cross-attention. Decode shapes
run the decoder against a fixed encoder memory; long_500k uses windowed
decoder self-attention (window=8192).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-medium", family="audio",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    dec_layers=12, cross_attention=True, frontend_embed_len=512,
    source="arXiv:2308.11596",
)
