"""Llama-4 Maverick 400B-A17B — interleaved MoE (every 2nd block:
128 routed experts top-1 + 1 shared), early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E family]."""
from repro.configs.base import ModelConfig, MoEConfig, TrainConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    moe=MoEConfig(num_experts=128, experts_per_token=1,
                  num_shared_experts=1, d_ff_expert=8192,
                  moe_every=2),   # 1 MoE : 1 dense interleave
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    notes="expert-parallel over the model axis; adafactor + microbatching; "
          "long_500k uses window=8192",
)
TRAIN = TrainConfig(optimizer="adafactor", remat=True, microbatch=8)
