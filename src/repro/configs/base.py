"""Config system: dataclass configs for models, shapes, training, FL and mesh.

Every assigned architecture lives in ``src/repro/configs/<id>.py`` (literal id
as filename, loaded via importlib) and exports ``CONFIG: ModelConfig``.
"""
from __future__ import annotations

import dataclasses
import importlib.util
import pathlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

CONFIG_DIR = pathlib.Path(__file__).parent


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0              # routed experts
    experts_per_token: int = 1        # top-k
    num_shared_experts: int = 0
    d_ff_expert: int = 0              # per-expert hidden dim
    router_aux_loss: float = 0.01     # load-balance loss weight
    capacity_factor: float = 1.25
    moe_every: int = 1                # k: every k-th block is MoE (Llama 4
    #                                   Maverick interleaves 1 MoE : 1 dense)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0              # 0 => full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD configuration."""
    state_dim: int = 64
    head_dim: int = 64                # Mamba2 P
    expand: int = 2                   # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM: indices of sLSTM blocks; the rest are mLSTM."""
    slstm_every: int = 0              # 0 => all mLSTM; k => every k-th block sLSTM
    proj_factor: float = 2.0          # mLSTM up-projection factor


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 => d_model // num_heads
    # attention
    rope_theta: float = 10000.0
    window: int = 0                   # 0 => full attention; >0 => sliding window
    causal: bool = True
    # hybrid (zamba2): one *shared* attention block applied every `attn_every`
    # mamba blocks (shared weights, Zamba-style).
    attn_every: int = 0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # enc-dec (audio): decoder layer count; num_layers is the encoder depth.
    dec_layers: int = 0
    cross_attention: bool = False
    # modality frontend stub: >0 => inputs are precomputed embeddings of this
    # many prefix positions (vlm patches / audio frames) fed alongside tokens.
    frontend_embed_len: int = 0
    # norm / activation
    norm_eps: float = 1e-5
    act: str = "swiglu"               # swiglu | gelu
    tie_embeddings: bool = False
    # dtypes
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # notes for DESIGN/EXPERIMENTS (e.g. long_500k handling)
    notes: str = ""
    source: str = ""                  # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def param_count(self) -> int:
        """Analytical parameter count (embeddings + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        n = v * d                       # token embedding
        if not self.tie_embeddings:
            n += v * d                  # lm head
        n += self.num_layers * self.block_param_count()
        if self.cross_attention and self.dec_layers:
            n += self.dec_layers * self.decoder_block_param_count()
        return n

    def active_param_count(self) -> int:
        """Per-token active params (MoE counts only routed top-k + shared)."""
        d, v = self.d_model, self.vocab_size
        n = v * d + (0 if self.tie_embeddings else v * d)
        n += self.num_layers * self.block_param_count(active_only=True)
        if self.cross_attention and self.dec_layers:
            n += self.dec_layers * self.decoder_block_param_count()
        return n

    # -- per-block parameter model -------------------------------------------
    def attn_param_count(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        if self.mla is not None:
            m = self.mla
            qd = (m.qk_nope_head_dim + m.qk_rope_head_dim) * self.num_heads
            n = d * m.kv_lora_rank + m.kv_lora_rank * (
                (m.qk_nope_head_dim + m.v_head_dim) * self.num_heads)
            n += d * m.qk_rope_head_dim   # shared rope key
            n += (d * m.q_lora_rank + m.q_lora_rank * qd) if m.q_lora_rank else d * qd
            n += self.num_heads * m.v_head_dim * d
            return n
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        return q + kv + o

    def mlp_param_count(self, d_ff: int) -> int:
        mult = 3 if self.act == "swiglu" else 2
        return mult * self.d_model * d_ff

    def block_param_count(self, active_only: bool = False) -> int:
        d = self.d_model
        if self.family == "ssm" and self.xlstm is not None:
            d_in = int(self.xlstm.proj_factor * d)
            return 2 * d * d_in + 2 * d_in * d + 4 * d  # rough mLSTM block
        if self.ssm is not None and self.family in ("ssm", "hybrid"):
            s = self.ssm
            d_in = s.expand * d
            n_heads = d_in // s.head_dim
            mamba = (d * (2 * d_in + 2 * s.state_dim * (d_in // s.head_dim if False else 1) )  # simplified
                     )
            # canonical mamba2: in_proj d->(2*d_in + 2*n_groups*state + n_heads)
            mamba = d * (2 * d_in + 2 * s.state_dim + n_heads) + d_in * d + 2 * d
            if self.family == "hybrid":
                # shared attention block amortized over attn_every mamba blocks
                if self.attn_every:
                    shared = self.attn_param_count() + self.mlp_param_count(self.d_ff)
                    mamba += shared // max(1, self.num_layers)
                return mamba
            return mamba
        attn = self.attn_param_count()
        if self.moe is not None and self.moe.num_experts > 0:
            experts = self.moe.num_experts
            active = self.moe.experts_per_token
            shared = self.moe.num_shared_experts
            e_ff = self.moe.d_ff_expert or self.d_ff
            per_e = self.mlp_param_count(e_ff)
            router = self.d_model * experts
            total_e = experts if not active_only else active
            moe_block = attn + router + (total_e + shared) * per_e \
                + 2 * self.d_model
            k = max(1, self.moe.moe_every)
            if k > 1:   # interleaved: (k-1) dense blocks per MoE block
                dense_block = attn + self.mlp_param_count(self.d_ff) \
                    + 2 * self.d_model
                return (moe_block + (k - 1) * dense_block) // k
            return moe_block
        return attn + self.mlp_param_count(self.d_ff) + 2 * self.d_model

    def decoder_block_param_count(self) -> int:
        return self.attn_param_count() * 2 + self.mlp_param_count(self.d_ff) + 3 * self.d_model


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Training / FL configuration (the paper's experiment knobs)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"          # adamw | adafactor | sgdm
    base_lr: float = 1.5e-4
    weight_decay: float = 1e-5
    lr_schedule: str = "cosine"       # cosine | fixed | cyclic   (paper §5.9)
    batch_size: int = 1024
    warmup_steps: int = 0
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 0.0
    remat: bool = False
    microbatch: int = 0               # 0 => no grad accumulation


@dataclass(frozen=True)
class SSLConfig:
    method: str = "moco_v3"           # moco_v3 | simclr | byol
    temperature: float = 0.2
    momentum: float = 0.99
    proj_dim: int = 256
    proj_hidden: int = 4096
    pred_hidden: int = 4096
    align_weight: float = 0.01        # alpha (representation alignment)


@dataclass(frozen=True)
class FLConfig:
    num_clients: int = 10
    clients_per_round: int = 0        # 0 => all
    rounds: int = 180
    local_epochs: int = 3
    # schedule: e2e | layerwise | lw_fedssl | progressive | fll_dd
    schedule: str = "lw_fedssl"
    rounds_per_stage: Tuple[int, ...] = ()   # empty => uniform R/S
    stage_allocation: str = "uniform"        # uniform | left_skewed | right_skewed
    weight_transfer: bool = True             # L_{s-1} -> L_s init (paper §B.2)
    depth_dropout: float = 0.0               # FLL+DD frozen-layer drop rate
    include_heads: bool = True               # exchange SSL heads; False =
    #                                          encoder-only wire/accounting
    #                                          (heads revert to the server
    #                                          copy each round — the sim
    #                                          keeps no per-client state)
    server_epochs: int = 3                   # server-side calibration epochs
    aux_fraction: float = 0.1                # |D_g| as fraction (paper §5.4)
    dirichlet_beta: float = 0.0              # 0 => IID partition
    seed: int = 0


# ---------------------------------------------------------------------------
# Registry: load src/repro/configs/<id>.py by literal arch id
# ---------------------------------------------------------------------------
ARCH_IDS = [
    "zamba2-2.7b",
    "internlm2-1.8b",
    "xlstm-125m",
    "internvl2-1b",
    "seamless-m4t-medium",
    "mistral-large-123b",
    "llama4-maverick-400b-a17b",
    "internlm2-20b",
    "starcoder2-15b",
    "deepseek-v2-236b",
    # paper's own backbone
    "vit-tiny",
]

_cache: dict = {}


def load_arch(arch_id: str) -> ModelConfig:
    if arch_id in _cache:
        return _cache[arch_id]
    path = CONFIG_DIR / f"{arch_id}.py"
    if not path.exists():
        raise KeyError(f"unknown arch '{arch_id}'; available: {ARCH_IDS}")
    spec = importlib.util.spec_from_file_location(
        f"repro.configs._arch_{arch_id.replace('-', '_').replace('.', '_')}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)  # type: ignore[union-attr]
    cfg = mod.CONFIG
    _cache[arch_id] = cfg
    return cfg


def load_train(arch_id: str) -> "TrainConfig":
    """Per-arch training config (optimizer/remat/microbatch) or defaults."""
    path = CONFIG_DIR / f"{arch_id}.py"
    spec = importlib.util.spec_from_file_location(
        f"repro.configs._train_{arch_id.replace('-', '_').replace('.', '_')}",
        path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)  # type: ignore[union-attr]
    return getattr(mod, "TRAIN", TrainConfig())


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (<=2 layers, d<=512)."""
    base = dict(
        num_layers=2,
        d_model=min(cfg.d_model, 256),
        num_heads=min(cfg.num_heads, 4),
        num_kv_heads=min(cfg.num_kv_heads, 2),
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        head_dim=64 if cfg.head_dim else 0,
        dec_layers=2 if cfg.dec_layers else 0,
        frontend_embed_len=min(cfg.frontend_embed_len, 16),
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.moe is not None:
        base["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 4),
            experts_per_token=min(cfg.moe.experts_per_token, 2),
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            d_ff_expert=min(cfg.moe.d_ff_expert or 512, 256))
    if cfg.mla is not None:
        base["mla"] = MLAConfig(kv_lora_rank=64, q_lora_rank=0,
                                qk_nope_head_dim=32, qk_rope_head_dim=16,
                                v_head_dim=32)
    if cfg.ssm is not None:
        base["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=32,
                                          chunk_size=32)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
