"""xLSTM-125M — sLSTM + mLSTM blocks [arXiv:2405.04517].

12 blocks in groups of 6 (5 mLSTM + 1 sLSTM); layer-wise stage = one group
(the paper's "layer" may be a block of layers). d_ff=0: xLSTM blocks carry
their own up/down projections (proj_factor=2).
long_500k: native (recurrent state is O(1)).
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    arch_id="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=6, proj_factor=2.0),
    source="arXiv:2405.04517",
)
