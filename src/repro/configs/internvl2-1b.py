"""InternVL2-1B — InternViT + InternLM2-backbone VLM [arXiv:2404.16821].

The language decoder (Qwen2-0.5B-scale InternLM2 family config). The vision
frontend (InternViT + MLP projector) is a STUB per the assignment carve-out:
input_specs() supplies 256 precomputed patch embeddings per sample
(frontend_embed_len) concatenated ahead of the token embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64,
    frontend_embed_len=256,
    source="arXiv:2404.16821",
    notes="vision encoder stubbed to patch embeddings; "
          "long_500k uses window=8192",
)
