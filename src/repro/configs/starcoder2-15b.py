"""StarCoder2-15B — dense GQA with RoPE [arXiv:2402.19173]."""
from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
    d_ff=24576, vocab_size=49152, act="gelu",
    source="arXiv:2402.19173",
    notes="StarCoder2 trains with a 4k sliding window natively; "
          "long_500k uses window=8192",
)
TRAIN = TrainConfig(optimizer="adamw", remat=True, microbatch=4)
