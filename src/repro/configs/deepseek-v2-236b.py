"""DeepSeek-V2 (236B) — MLA (kv_lora=512) + MoE 2 shared + 160 routed top-6
[arXiv:2405.04434]. d_ff=1536 is the per-expert hidden dim."""
from repro.configs.base import ModelConfig, MLAConfig, MoEConfig, TrainConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=1536, vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, experts_per_token=6,
                  num_shared_experts=2, d_ff_expert=1536),
    source="arXiv:2405.04434",
    notes="MLA latent cache makes long_500k decode practical: "
          "cache is (seq, 512+64) per layer, context-parallel sharded; "
          "long_500k uses window=8192 on the latent cache",
)
TRAIN = TrainConfig(optimizer="adafactor", remat=True, microbatch=8)
