"""ViT-Tiny — the paper's own encoder backbone (Dosovitskiy et al., 2021).

32x32x3 inputs, patch size 4, 12 blocks, d=192, 3 heads, MLP 768, GELU;
MoCo v3 heads attach on top (repro.core.heads). This is the FL/SSL
experiment backbone, not part of the 40-pair dry-run table.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="vit-tiny", family="dense",
    num_layers=12, d_model=192, num_heads=3, num_kv_heads=3,
    d_ff=768, vocab_size=0, causal=False, act="gelu",
    source="arXiv:2010.11929 (ViT); paper Section 5.1",
)
