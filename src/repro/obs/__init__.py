"""Structured tracing + metrics for the FL stack (docs/observability.md).

Public surface:

  Tracer / NoopTracer / NOOP_TRACER      nested spans, instants, virtual
                                         tracks (repro.obs.trace)
  MetricsRegistry / NOOP_METRICS         counters, gauges, histograms
                                         (repro.obs.metrics)
  Observability / make_obs / NOOP_OBS    the bundle the stack threads
                                         through itself (repro.obs.core)
  write_jsonl / read_jsonl / write_chrome_trace / write_metrics_csv /
  write_history_json / format_round_line / ConsoleRenderer
                                         exporters (repro.obs.export)

Everything is off by default: the driver, engines, transport and fleet
simulator hold ``NOOP_OBS`` unless a real bundle is passed in
(``run_fedssl(obs=...)`` / ``--trace`` / ``--metrics`` / ``--profile-dir``
on ``repro.launch.train``). Analyze traces with
``python -m repro.launch.trace``.
"""
from repro.obs.core import NOOP_OBS, Observability, make_obs
from repro.obs.export import (ConsoleRenderer, chrome_trace_doc,
                              format_round_line, metrics_csv_text,
                              read_jsonl, trace_header, write_chrome_trace,
                              write_history_json, write_jsonl,
                              write_metrics_csv)
from repro.obs.health import (HEALTH_VERSION, Alert, HealthMonitor,
                              write_health_json)
from repro.obs.metrics import NOOP_METRICS, MetricsRegistry
from repro.obs.trace import NOOP_TRACER, NoopTracer, Span, Tracer, is_tracing

__all__ = [
    "NOOP_OBS", "Observability", "make_obs",
    "ConsoleRenderer", "chrome_trace_doc", "format_round_line",
    "metrics_csv_text", "read_jsonl", "trace_header", "write_chrome_trace",
    "write_history_json", "write_jsonl", "write_metrics_csv",
    "HEALTH_VERSION", "Alert", "HealthMonitor", "write_health_json",
    "NOOP_METRICS", "MetricsRegistry",
    "NOOP_TRACER", "NoopTracer", "Span", "Tracer", "is_tracing",
]
