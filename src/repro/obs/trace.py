"""Span-based tracer for the FL stack.

One ``Tracer`` records one run as a flat, append-only event list. Spans
nest (``run > round > {download, local_train, upload, aggregate,
calibrate}`` with per-client / per-codec children); each completed span
becomes one Chrome ``trace_event``-shaped record::

    {"ph": "X", "name", "cat", "ts", "dur", "pid", "tid",
     "seq", "parent", "depth", "args"}

``ts``/``dur`` are microseconds (wall-clock by default). ``seq`` is the
span *open* order and ``parent`` the enclosing span's ``seq``, so the
nesting structure is reconstructible from the flat list and — unlike the
timestamps — fully deterministic for a seeded run (the determinism tests
compare ``structure()`` across runs). ``args`` carries the attached
attributes (stage, wire bytes, codec, participants, ...).

Besides wall-clock spans the tracer holds named *virtual tracks*
(``virtual_span``): spans with caller-supplied timestamps on their own
``tid``, used by the fleet simulator to lay each client's simulated round
out on the simulated timeline. Exporters render tracks as threads, so a
simulated 1000-client round reads like a real profile in Perfetto.

``NOOP_TRACER`` implements the same surface as no-ops; instrumented code
holds an unconditional reference and pays only an attribute lookup and an
empty context manager when observability is off (<2% on the engine
bench — see docs/observability.md).
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

MAIN_TRACK = "main"


class Span:
    """An open span; a context manager. ``set(**attrs)`` attaches
    attributes any time before exit."""

    __slots__ = ("tracer", "name", "cat", "args", "seq", "parent",
                 "depth", "_t0")

    def __init__(self, tracer, name, cat, args, seq, parent, depth, t0):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.seq = seq
        self.parent = parent
        self.depth = depth
        self._t0 = t0

    def set(self, **attrs):
        self.args.update(attrs)
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.tracer._close(self)
        return False


class Tracer:
    """Collects events; see module docstring for the record shape."""

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._epoch = clock()
        self.events: List[Dict[str, Any]] = []
        self._stack: List[Span] = []
        self._seq = 0
        self._tracks: Dict[str, int] = {MAIN_TRACK: 0}
        self.meta: Dict[str, Any] = {}

    # -- clock ---------------------------------------------------------------
    def _now_us(self) -> float:
        return (self._clock() - self._epoch) * 1e6

    # -- spans ---------------------------------------------------------------
    def span(self, name: str, cat: str = "fl", **attrs) -> Span:
        parent = self._stack[-1].seq if self._stack else None
        s = Span(self, name, cat, dict(attrs), self._seq, parent,
                 len(self._stack), self._now_us())
        self._seq += 1
        self._stack.append(s)
        return s

    def _close(self, span: Span):
        top = self._stack.pop()
        assert top is span, (top.name, span.name)
        t1 = self._now_us()
        self.events.append({
            "ph": "X", "name": span.name, "cat": span.cat,
            "ts": span._t0, "dur": t1 - span._t0, "pid": 0, "tid": 0,
            "seq": span.seq, "parent": span.parent, "depth": span.depth,
            "args": span.args,
        })

    def instant(self, name: str, cat: str = "fl", **attrs):
        """A zero-duration marker event (``ph: "i"``) at the current
        position in the span stack."""
        parent = self._stack[-1].seq if self._stack else None
        self.events.append({
            "ph": "i", "name": name, "cat": cat, "ts": self._now_us(),
            "dur": 0.0, "pid": 0, "tid": 0, "seq": self._seq,
            "parent": parent, "depth": len(self._stack), "args": dict(attrs),
        })
        self._seq += 1

    def virtual_span(self, name: str, track: str, t0_s: float, dur_s: float,
                     cat: str = "sim", **attrs):
        """A completed span with caller-supplied (simulated) timestamps on
        a named virtual track — its own ``tid``, seconds in, µs out."""
        tid = self._tracks.setdefault(track, len(self._tracks))
        parent = self._stack[-1].seq if self._stack else None
        self.events.append({
            "ph": "X", "name": name, "cat": cat, "ts": t0_s * 1e6,
            "dur": dur_s * 1e6, "pid": 0, "tid": tid, "seq": self._seq,
            "parent": parent, "depth": len(self._stack), "args": dict(attrs),
        })
        self._seq += 1

    # -- views ---------------------------------------------------------------
    @property
    def tracks(self) -> Dict[str, int]:
        return dict(self._tracks)

    def structure(self):
        """The timestamp-free view the determinism tests compare: one
        ``(seq, parent, depth, name, cat, tid, args)`` tuple per event.
        ``mem.``-prefixed args (the live device-memory watermarks the
        driver attaches to round spans) are environment noise, not
        structure, and are dropped here."""
        return [(e["seq"], e["parent"], e["depth"], e["name"], e["cat"],
                 e["tid"], tuple(sorted(
                     (k, v) for k, v in e["args"].items()
                     if not k.startswith("mem."))))
                for e in self.events]


class _NoopSpan:
    __slots__ = ()

    def set(self, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


class NoopTracer:
    """Same surface as ``Tracer``; does nothing. A singleton
    (``NOOP_TRACER``) so disabled instrumentation allocates nothing."""

    events: List[Dict[str, Any]] = []
    meta: Dict[str, Any] = {}
    _span = _NoopSpan()

    def span(self, name, cat="fl", **attrs):
        return self._span

    def instant(self, name, cat="fl", **attrs):
        pass

    def virtual_span(self, name, track, t0_s, dur_s, cat="sim", **attrs):
        pass

    @property
    def tracks(self):
        return {}

    def structure(self):
        return []


NOOP_TRACER = NoopTracer()


def is_tracing(tracer) -> bool:
    """True when ``tracer`` actually records (not the no-op)."""
    return isinstance(tracer, Tracer)
