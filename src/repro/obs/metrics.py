"""Typed metrics registry: counters, gauges, histograms.

The FL stack's quantitative telemetry — wire bytes per direction,
rounds/sec, jit recompiles, residual norms, simulated fleet energy and
wall-clock — is recorded here rather than printed: the registry is the
single source the CSV/JSON exporters, the trace CLI and the benches read.

  counter    monotonically increasing total (``inc``).
  gauge      last-written value (``set``).
  histogram  streaming summary of observations (``observe``): count, sum,
             min, max — mean derives; bounded memory, no reservoir.

Instruments are create-on-first-use (``registry.counter("wire.up_bytes")``)
and a ``NOOP_METRICS`` singleton mirrors the surface with no-ops so the
instrumented call sites are unconditional. ``to_dict()`` is the versioned
export form that ``repro.obs.export.write_metrics_csv`` flattens and
``benchmarks.schemas.validate_metrics_csv`` checks.
"""
from __future__ import annotations

from typing import Any, Dict

METRICS_VERSION = 1


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0):
        self.value += v


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)


class Histogram:
    __slots__ = ("count", "total", "min", "max")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float):
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0}
        return {"count": self.count, "sum": self.total, "min": self.min,
                "max": self.max, "mean": self.mean}


class MetricsRegistry:
    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        return self._histograms.setdefault(name, Histogram())

    def to_dict(self) -> Dict[str, Any]:
        """Versioned export form, deterministically key-ordered."""
        return {
            "version": METRICS_VERSION,
            "counters": {k: self._counters[k].value
                         for k in sorted(self._counters)},
            "gauges": {k: self._gauges[k].value
                       for k in sorted(self._gauges)},
            "histograms": {k: self._histograms[k].summary()
                           for k in sorted(self._histograms)},
        }


class _NoopInstrument:
    __slots__ = ()
    value = 0.0
    count = 0

    def inc(self, v=1.0):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass


class NoopMetrics:
    """Same surface as ``MetricsRegistry``; records nothing."""

    _noop = _NoopInstrument()

    def counter(self, name):
        return self._noop

    def gauge(self, name):
        return self._noop

    def histogram(self, name):
        return self._noop

    def to_dict(self):
        return {"version": METRICS_VERSION, "counters": {}, "gauges": {},
                "histograms": {}}


NOOP_METRICS = NoopMetrics()
