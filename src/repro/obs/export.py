"""Exporters: JSONL event stream, Chrome ``trace_event`` JSON, metrics
CSV, and the console round-line renderer.

  JSONL        one header line (``{"kind": "repro-trace", "version", ...
               run metadata}``) followed by one event object per line —
               the machine-readable stream ``repro.launch.trace`` and the
               benches analyze.
  Chrome       ``{"traceEvents": [...], "displayTimeUnit": "ms"}`` with
               complete (``"X"``) / instant (``"i"``) events plus
               ``thread_name`` metadata for the virtual tracks; loads
               directly in Perfetto / ``chrome://tracing``. Validated by
               ``benchmarks.schemas.validate_chrome_trace``.
  metrics CSV  ``metric,type,field,value`` rows flattened from
               ``MetricsRegistry.to_dict()`` (validated by
               ``benchmarks.schemas.validate_metrics_csv``).
  console      ``format_round_line`` is the one formatter for the
               per-round progress line (the driver and both launcher
               modes route through it), and ``ConsoleRenderer`` optionally
               renders it as a live single-line (``\\r``) status.
"""
from __future__ import annotations

import io
import json
import pathlib
import sys
from typing import Any, Dict, List, Optional, Tuple

TRACE_KIND = "repro-trace"
TRACE_VERSION = 1
METRICS_CSV_HEADER = "metric,type,field,value"


# ---------------------------------------------------------------------------
# JSONL event stream
# ---------------------------------------------------------------------------
def trace_header(tracer, **meta) -> Dict[str, Any]:
    h = {"kind": TRACE_KIND, "version": TRACE_VERSION,
         "tracks": tracer.tracks}
    h.update(tracer.meta)
    h.update(meta)
    return h


def write_jsonl(tracer, path, **meta) -> pathlib.Path:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w") as f:
        f.write(json.dumps(trace_header(tracer, **meta)) + "\n")
        for e in tracer.events:
            f.write(json.dumps(e) + "\n")
    return path


def read_jsonl(path) -> Tuple[Dict[str, Any], List[Dict[str, Any]]]:
    """(header, events) from a JSONL trace; validates the header kind."""
    lines = pathlib.Path(path).read_text().splitlines()
    if not lines:
        raise ValueError(f"{path}: empty trace")
    header = json.loads(lines[0])
    if header.get("kind") != TRACE_KIND:
        raise ValueError(f"{path}: not a {TRACE_KIND} file "
                         f"(kind={header.get('kind')!r})")
    return header, [json.loads(ln) for ln in lines[1:] if ln.strip()]


# ---------------------------------------------------------------------------
# Chrome trace_event JSON (Perfetto / chrome://tracing)
# ---------------------------------------------------------------------------
def chrome_trace_doc(tracer, **meta) -> Dict[str, Any]:
    events: List[Dict[str, Any]] = []
    for name, tid in sorted(tracer.tracks.items(), key=lambda kv: kv[1]):
        events.append({"ph": "M", "name": "thread_name", "pid": 0,
                       "tid": tid, "args": {"name": name}})
    for e in tracer.events:
        ev = {"ph": e["ph"], "name": e["name"], "cat": e["cat"],
              "ts": e["ts"], "pid": e["pid"], "tid": e["tid"],
              "args": e["args"]}
        if e["ph"] == "X":
            ev["dur"] = e["dur"]
        else:                      # instants need an explicit scope
            ev["s"] = "t"
        events.append(ev)
    doc = {"traceEvents": events, "displayTimeUnit": "ms",
           "otherData": {"kind": TRACE_KIND, "version": TRACE_VERSION,
                         **tracer.meta, **meta}}
    return doc


def write_chrome_trace(tracer, path, **meta) -> pathlib.Path:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(chrome_trace_doc(tracer, **meta)))
    return path


# ---------------------------------------------------------------------------
# metrics CSV
# ---------------------------------------------------------------------------
def metrics_csv_text(registry) -> str:
    """Flatten ``registry.to_dict()`` into ``metric,type,field,value``
    rows (histograms contribute one row per summary field)."""
    d = registry.to_dict()
    out = io.StringIO()
    out.write(METRICS_CSV_HEADER + "\n")
    for name, v in d["counters"].items():
        out.write(f"{name},counter,value,{v!r}\n")
    for name, v in d["gauges"].items():
        out.write(f"{name},gauge,value,{v!r}\n")
    for name, s in d["histograms"].items():
        for field, v in s.items():
            out.write(f"{name},histogram,{field},{v!r}\n")
    return out.getvalue()


def write_metrics_csv(registry, path) -> pathlib.Path:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(metrics_csv_text(registry))
    return path


def write_history_json(hist, path, **meta) -> pathlib.Path:
    """Dump an ``FLHistory`` via its versioned ``to_dict`` form — the one
    serialization traces, benches and checkpoints share."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    doc = hist.to_dict()
    if meta:
        doc["meta"] = meta
    path.write_text(json.dumps(doc, indent=1))
    return path


# ---------------------------------------------------------------------------
# console
# ---------------------------------------------------------------------------
def format_round_line(round_idx: int, rounds: int, stage: int, loss: float,
                      *, lr: Optional[float] = None,
                      down_mb: Optional[float] = None,
                      up_mb: Optional[float] = None,
                      wire_mb: Optional[float] = None,
                      extra: str = "") -> str:
    """The per-round progress line — single formatter for the driver and
    both launcher modes (it used to be copy-pasted between them)."""
    parts = [f"round {round_idx + 1}/{rounds} stage {stage} "
             f"loss {loss:.4f}"]
    if lr is not None:
        parts.append(f"lr {lr:.2e}")
    if down_mb is not None:
        parts.append(f"down {down_mb:.2f}MB")
    if up_mb is not None:
        parts.append(f"up {up_mb:.2f}MB")
    if wire_mb is not None:
        parts.append(f"wire {wire_mb:.2f}MB")
    line = " ".join(parts)
    return line + extra


class ConsoleRenderer:
    """Callable console sink for progress lines.

    ``live=True`` rewrites a single status line in place (``\\r``, padded
    to the previous width); ``live=False`` prints one line per call.
    Drop-in for the driver's ``log=`` callback; call ``close()`` (or use
    as a context manager) to terminate a live line with a newline."""

    def __init__(self, live: bool = False, stream=None):
        self.live = live
        self.stream = stream if stream is not None else sys.stdout
        self._last_len = 0

    def __call__(self, line: str):
        if self.live:
            pad = max(0, self._last_len - len(line))
            self.stream.write("\r" + line + " " * pad)
            self.stream.flush()
            self._last_len = len(line)
        else:
            self.stream.write(line + "\n")

    def close(self):
        if self.live and self._last_len:
            self.stream.write("\n")
            self.stream.flush()
            self._last_len = 0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
