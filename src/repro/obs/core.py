"""The ``Observability`` bundle the FL stack threads through itself.

One object carries the tracer, the metrics registry and the optional
``jax.profiler`` hook; ``run_fedssl(obs=...)``, the engines, the transport
and the fleet simulator all hold a reference (``NOOP_OBS`` by default —
everything off, near-zero overhead) and record unconditionally.

``make_obs(trace=..., metrics=..., profile_dir=...)`` builds an enabled
bundle; ``obs.export(...)`` writes whichever artifacts were requested
(JSONL trace, Chrome trace, metrics CSV). The profiler hooks are gated:
if ``jax.profiler`` is unavailable or fails to start (headless builds),
the run proceeds untraced rather than crashing.
"""
from __future__ import annotations

from typing import Optional

from repro.obs import export as export_mod
from repro.obs.metrics import NOOP_METRICS, MetricsRegistry
from repro.obs.trace import NOOP_TRACER, Tracer, is_tracing


class Observability:
    """Tracer + metrics + profiler hooks. Prefer ``make_obs``."""

    def __init__(self, tracer=NOOP_TRACER, metrics=NOOP_METRICS,
                 profile_dir: Optional[str] = None, health=None,
                 measure_resources: bool = False):
        self.tracer = tracer
        self.metrics = metrics
        self.profile_dir = profile_dir
        self.health = health
        # opt-in: the driver AOT-lowers each new stage's round program
        # and attaches measured cost_analysis attrs (res.*) to the
        # stage-opening round span — a few seconds per stage
        self.measure_resources = measure_resources
        self._profiling = False

    @property
    def enabled(self) -> bool:
        return (is_tracing(self.tracer)
                or isinstance(self.metrics, MetricsRegistry)
                or self.profile_dir is not None
                or self.health is not None)

    # -- jax.profiler hooks (gated: failure to start is non-fatal) ----------
    def start_profiler(self):
        if self.profile_dir is None or self._profiling:
            return
        try:
            import jax
            jax.profiler.start_trace(self.profile_dir)
            self._profiling = True
        except Exception as e:          # pragma: no cover - env dependent
            print(f"obs: jax.profiler unavailable ({e}); continuing")

    def stop_profiler(self):
        if not self._profiling:
            return
        try:
            import jax
            jax.profiler.stop_trace()
        except Exception as e:          # pragma: no cover - env dependent
            print(f"obs: jax.profiler stop failed ({e})")
        self._profiling = False

    # -- artifact export -----------------------------------------------------
    def export(self, *, trace_jsonl=None, chrome_trace=None,
               metrics_csv=None, health_json=None, **meta):
        """Write the requested artifacts; returns {kind: path}."""
        written = {}
        if trace_jsonl and is_tracing(self.tracer):
            written["trace_jsonl"] = export_mod.write_jsonl(
                self.tracer, trace_jsonl, **meta)
        if chrome_trace and is_tracing(self.tracer):
            written["chrome_trace"] = export_mod.write_chrome_trace(
                self.tracer, chrome_trace, **meta)
        if metrics_csv and isinstance(self.metrics, MetricsRegistry):
            written["metrics_csv"] = export_mod.write_metrics_csv(
                self.metrics, metrics_csv)
        if health_json and self.health is not None:
            from repro.obs.health import write_health_json
            write_health_json(health_json, self.health, **meta)
            written["health_json"] = health_json
        return written


NOOP_OBS = Observability()


def make_obs(*, trace: bool = False, metrics: bool = False,
             profile_dir: Optional[str] = None, clock=None,
             health: bool = False, halt_on_unhealthy: bool = False,
             measure_resources: bool = False,
             **meta) -> Observability:
    """Build an enabled bundle; extra kwargs become trace run metadata.
    ``health=True`` attaches a ``HealthMonitor`` the driver feeds each
    round; ``halt_on_unhealthy`` arms its halt-on-fatal hook."""
    if trace:
        tracer = Tracer(clock) if clock is not None else Tracer()
        tracer.meta.update(meta)
    else:
        tracer = NOOP_TRACER
    monitor = None
    if health or halt_on_unhealthy:
        from repro.obs.health import HealthMonitor
        monitor = HealthMonitor(halt_on_fatal=halt_on_unhealthy)
    return Observability(
        tracer=tracer,
        metrics=MetricsRegistry() if metrics else NOOP_METRICS,
        profile_dir=profile_dir, health=monitor,
        measure_resources=measure_resources)
