"""Streaming training-health monitor over the per-round FL signals.

The driver feeds every round's cheap scalars — mean loss, wire
compression ratio, straggler drops, jit-recompile count — into
``HealthMonitor.observe_round``; the monitor keeps streaming statistics
(Welford mean/variance for the loss, per-stage reference ratios) and
returns typed ``Alert``s the driver turns into ``health.*`` instant
events on the trace. Detectors:

  loss_nonfinite    NaN/inf round loss (fatal — the model is gone; no
                    later round recovers it)
  loss_spike        z-score of the round loss against the running
                    per-stage distribution exceeds ``loss_z``. Stage
                    transitions reset the statistics: a new depth has a
                    new loss scale, so cross-stage z-scores are noise.
  compression_drift the wire compression ratio moved more than
                    ``ratio_rtol`` relative to the first ratio observed
                    for the stage — a codec or spec regression, since
                    the ratio is structural for a fixed plan
  drop_rate         cumulative straggler drop rate exceeds
                    ``drop_rate_max`` after ``warmup`` rounds
  recompile_storm   jit cache entries grew on a round that did NOT open
                    a new stage — every legal retrace in the FL loop is
                    tied to a plan-signature change

Observation is read-only: the monitor never touches model state, RNG
chains, or the trace timeline beyond its own instants, so runs with the
monitor attached stay bit-identical to untraced runs (asserted in
tests). ``report()`` serializes to the schema-validated ``health.json``
(``benchmarks.schemas.validate_health_report``); ``should_halt`` is the
driver's opt-in halt-on-fatal hook, modeled on the privacy
epsilon-budget halt.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import List, Optional

HEALTH_VERSION = 1

ALERT_KINDS = ("loss_nonfinite", "loss_spike", "compression_drift",
               "drop_rate", "recompile_storm")
ALERT_LEVELS = ("warn", "fatal")


@dataclass(frozen=True)
class Alert:
    round: int
    kind: str
    level: str
    value: float
    message: str

    def to_dict(self) -> dict:
        v = self.value
        return {"round": self.round, "kind": self.kind,
                "level": self.level,
                "value": None if math.isnan(v) or math.isinf(v)
                else float(v),
                "message": self.message}


@dataclass
class HealthMonitor:
    loss_z: float = 4.0
    ratio_rtol: float = 0.25
    drop_rate_max: float = 0.5
    warmup: int = 5
    halt_on_fatal: bool = False

    alerts: List[Alert] = field(default_factory=list)
    rounds_observed: int = 0
    # Welford accumulators for the current stage's loss distribution
    _n: int = field(default=0, repr=False)
    _mean: float = field(default=0.0, repr=False)
    _m2: float = field(default=0.0, repr=False)
    _ref_ratio: Optional[float] = field(default=None, repr=False)
    _drops: int = field(default=0, repr=False)
    _contacted: int = field(default=0, repr=False)

    @property
    def fatal(self) -> bool:
        return any(a.level == "fatal" for a in self.alerts)

    @property
    def should_halt(self) -> bool:
        return self.halt_on_fatal and self.fatal

    def _alert(self, out, round_idx, kind, level, value, message):
        a = Alert(round=round_idx, kind=kind, level=level,
                  value=float(value), message=message)
        self.alerts.append(a)
        out.append(a)

    def observe_round(self, round_idx: int, *, loss: float,
                      compression_ratio: Optional[float] = None,
                      dropped: int = 0, participants: int = 0,
                      recompiles: int = 0,
                      new_stage: bool = False) -> List[Alert]:
        """Feed one round's signals; returns the alerts *this* round
        raised (all alerts accumulate on ``self.alerts``)."""
        out: List[Alert] = []
        self.rounds_observed += 1
        if new_stage:
            self._n, self._mean, self._m2 = 0, 0.0, 0.0
            self._ref_ratio = None

        loss = float(loss)
        if math.isnan(loss) or math.isinf(loss):
            self._alert(out, round_idx, "loss_nonfinite", "fatal", loss,
                        f"round loss is {loss!r}")
        else:
            if self._n >= max(2, self.warmup):
                std = math.sqrt(self._m2 / (self._n - 1))
                if std > 0.0:
                    z = abs(loss - self._mean) / std
                    if z > self.loss_z:
                        self._alert(
                            out, round_idx, "loss_spike", "warn", z,
                            f"loss {loss:.4g} is {z:.1f} sigma from the "
                            f"stage mean {self._mean:.4g}")
            self._n += 1
            d = loss - self._mean
            self._mean += d / self._n
            self._m2 += d * (loss - self._mean)

        if compression_ratio is not None \
                and math.isfinite(compression_ratio):
            if self._ref_ratio is None:
                self._ref_ratio = float(compression_ratio)
            else:
                rel = abs(compression_ratio / self._ref_ratio - 1.0)
                if rel > self.ratio_rtol:
                    self._alert(
                        out, round_idx, "compression_drift", "warn", rel,
                        f"compression ratio {compression_ratio:.3g} "
                        f"drifted {rel:.0%} from the stage reference "
                        f"{self._ref_ratio:.3g}")

        self._drops += int(dropped)
        self._contacted += int(participants) + int(dropped)
        if self.rounds_observed > self.warmup and self._contacted > 0:
            rate = self._drops / self._contacted
            if rate > self.drop_rate_max:
                self._alert(
                    out, round_idx, "drop_rate", "warn", rate,
                    f"cumulative straggler drop rate {rate:.0%} exceeds "
                    f"{self.drop_rate_max:.0%}")

        if recompiles > 0 and not new_stage:
            self._alert(
                out, round_idx, "recompile_storm", "warn",
                float(recompiles),
                f"{recompiles} jit recompile(s) on a round with no stage "
                f"transition")
        return out

    def report(self) -> dict:
        counts = {k: 0 for k in ALERT_KINDS}
        for a in self.alerts:
            counts[a.kind] = counts.get(a.kind, 0) + 1
        return {
            "version": HEALTH_VERSION,
            "rounds_observed": self.rounds_observed,
            "fatal": self.fatal,
            "halted": self.should_halt,
            "counts": counts,
            "alerts": [a.to_dict() for a in self.alerts],
            "config": {"loss_z": self.loss_z,
                       "ratio_rtol": self.ratio_rtol,
                       "drop_rate_max": self.drop_rate_max,
                       "warmup": self.warmup,
                       "halt_on_fatal": self.halt_on_fatal},
        }


def write_health_json(path, monitor: HealthMonitor, **meta) -> dict:
    """Serialize ``monitor.report()`` (+ caller metadata) to ``path``.
    Returns the written document."""
    doc = monitor.report()
    if meta:
        doc["meta"] = {k: v for k, v in sorted(meta.items())}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return doc
