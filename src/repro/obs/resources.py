"""Measured resource attribution from the compiled XLA round programs.

The analytic roofline (``repro.roofline.client_costs``) *predicts* the
paper's memory/GFLOPs/comm reductions from the ViT config; this module
*measures* them from the programs the engines actually lower and run:

  FLOPs    ``Lowered.cost_analysis()`` of each engine's round unit per
           distinct plan signature. The ViT layer scans are fully
           unrolled while lowering (``unrolled_scans``) because XLA's
           HLO cost analysis counts a rolled while-loop body once — the
           rolled programs we *run* would under-count by the trip count.
           Lowering needs no XLA compile, so a whole schedule's
           signatures measure in seconds.
  memory   ``Compiled.memory_analysis()`` (argument/output/temp/peak
           bytes). Compilation is the expensive step (~tens of seconds
           per program on one CPU), so only the signature the analytic
           model predicts as the schedule's peak is compiled.
  live     ``device.memory_stats()`` watermarks on accelerators, RSS
           from ``/proc/self`` on CPU — cheap enough for the driver to
           attach to every round span (``mem.*`` attributes, excluded
           from ``Tracer.structure()`` so traced-run determinism checks
           ignore them).

Normalization contract: the sequential engine's unit is one jit'd local
step over one batch (per-sample FLOPs = flops / batch); the vmap
engine's unit is the whole fused round program lowered at ``clients``
stacked participants and scan trip count 1 (per-sample =
flops / (clients * batch)). Schedule totals multiply per-sample costs by
``local_epochs`` and sum over the round plans — the same accounting as
``client_costs.schedule_costs`` — so measured and analytic columns are
directly comparable. Stochastic depth-dropout savings (FLL+DD) are an
expected-value claim the dense compiled program cannot exhibit, so both
columns here count gated layers densely; the dropout-adjusted totals
live only in the analytic full-scale table. See docs/observability.md
("Measured resources") for the documented tolerances.
"""
from __future__ import annotations

import contextlib
import os

import jax
import numpy as np

from repro.models import scan_cfg
from repro.roofline.analysis import cost_dict, memory_dict

RESOURCES_VERSION = 1

# documented measured-vs-analytic agreement bounds (per plan signature,
# reduced vit-tiny measurement config): XLA counts a handful of ops the
# analytic model folds into its 2:1 backward ratio (layernorm, softmax,
# EMA update, optimizer), so measured flops sit a few percent *above*
# analytic; buffer assignment double-books some live ranges, so measured
# peak bytes can sit well above the analytic live-set floor.
FLOPS_RTOL = 0.30          # |measured/analytic - 1| <= 0.30
MEMORY_FACTOR = 3.0        # analytic/3 <= measured peak <= 3*analytic


@contextlib.contextmanager
def unrolled_scans():
    """Fully unroll the ViT layer scans while lowering measurement
    programs. Only the lowered artifact this context produces is
    unrolled — jit executables traced outside it stay rolled, and
    ``jit.lower()`` does not populate the executable cache, so
    measurement never perturbs (or recompiles) the programs a live run
    executes."""
    prev = scan_cfg.UNROLL
    scan_cfg.UNROLL = True
    try:
        yield
    finally:
        scan_cfg.UNROLL = prev


# ---------------------------------------------------------------------------
# live device-memory watermarks
# ---------------------------------------------------------------------------
def _peak_rss_bytes() -> int:
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) * 1024
    except (OSError, ValueError, IndexError):
        pass
    return 0


def device_memory_snapshot(device=None) -> dict:
    """Live memory watermark for ``device`` (default: first device).

    Accelerator backends expose allocator stats via
    ``device.memory_stats()``; the CPU backend returns None, so there we
    fall back to the process RSS (``/proc/self/statm``) and its
    high-water mark (``VmHWM``) — CPU arrays live on the host heap, so
    RSS *is* the device watermark. ``source`` records which path
    produced the numbers."""
    if device is None:
        device = jax.devices()[0]
    stats = None
    try:
        stats = device.memory_stats()
    except Exception:
        stats = None
    if stats:
        in_use = int(stats.get("bytes_in_use", 0))
        return {"source": "device", "bytes_in_use": in_use,
                "peak_bytes": int(stats.get("peak_bytes_in_use", in_use))}
    try:
        with open("/proc/self/statm") as f:
            rss = int(f.read().split()[1]) * os.sysconf("SC_PAGE_SIZE")
        return {"source": "rss", "bytes_in_use": rss,
                "peak_bytes": _peak_rss_bytes() or rss}
    except (OSError, ValueError, IndexError):
        return {"source": "none", "bytes_in_use": 0, "peak_bytes": 0}


def memory_span_attrs(device=None) -> dict:
    """``device_memory_snapshot`` as ``mem.``-prefixed span attributes.
    The ``mem.`` prefix is load-bearing: ``Tracer.structure()`` drops
    those keys so traced-vs-untraced (and traced-vs-traced) structure
    comparisons stay deterministic across machines."""
    snap = device_memory_snapshot(device)
    return {"mem.source": snap["source"],
            "mem.bytes_in_use": snap["bytes_in_use"],
            "mem.peak_bytes": snap["peak_bytes"]}


# ---------------------------------------------------------------------------
# measurement configuration
# ---------------------------------------------------------------------------
def measurement_config(arch: str = "vit-tiny", *, num_layers: int = 4,
                       batch_size: int = 8):
    """Reduced measurement shape: ``num_layers`` blocks at shrunk width
    so one CPU lowers every plan signature in seconds. Resource *ratios*
    between schedules are structural (per-block costs cancel), so they
    survive the shrink; the analytic columns are evaluated on this same
    config, which is what makes measured-vs-analytic a like-for-like
    check. Full-scale comm ratios never need this — the wire walk is
    abstract (``repro.launch.trace.emit_comm_trace``)."""
    from repro.configs.base import SSLConfig, TrainConfig, load_arch, reduced
    cfg = reduced(load_arch(arch), num_layers=num_layers,
                  num_heads=2, num_kv_heads=2)
    ssl = SSLConfig()
    train = TrainConfig(batch_size=batch_size)
    return cfg, ssl, train


def _measurement_engine(engine_name, cfg, ssl, train, fl):
    from repro.core import ssl as ssl_mod
    from repro.federated import engine as engine_mod
    from repro.federated import transport as transport_mod
    from repro.optim import make_optimizer
    bs = train.batch_size
    shard = 2 * bs
    images = np.zeros((fl.num_clients * shard, 32, 32, 3), np.float32)
    client_indices = [np.arange(i * shard, (i + 1) * shard)
                      for i in range(fl.num_clients)]
    return engine_mod.make_engine(
        engine_name, encoder=ssl_mod.make_vit_encoder(cfg), ssl_cfg=ssl,
        opt=make_optimizer(train), fl=fl, train_cfg=train, images=images,
        client_indices=client_indices,
        transport=transport_mod.Transport("fp32"))


def _plan_sig(plan):
    return (plan.sub_layers, plan.active_from, plan.align,
            plan.depth_dropout)


def stage_cost_attrs(engine, plan, *, clients: int = 1) -> dict:
    """Measured cost attributes for one stage's round program —
    ``res.``-prefixed, suitable for ``span.set(**attrs)`` on the round
    span that opens a stage. Lowering only (no compile): a few seconds
    per new stage, opt-in via ``make_obs(measure_resources=True)``."""
    with unrolled_scans():
        low = engine.lower_round(plan, clients=clients)
    cost = cost_dict(low)
    denom = engine.train_cfg.batch_size * (
        clients if engine.name == "vmap" else 1)
    flops = float(cost.get("flops", 0.0))
    return {"res.flops": flops,
            "res.flops_per_sample": flops / denom,
            "res.bytes_accessed": float(cost.get("bytes accessed", 0.0))}


def program_memory_analytic(cfg, ssl, train, plan, engine_name: str, *,
                            clients: int = 1) -> dict:
    """Analytic estimate of the bytes the *compiled round program*
    holds — not the paper's idealized client footprint. Both engines
    keep the full state + AdamW moments resident (inputs and outputs
    are not donated), so arguments/outputs are schedule-invariant and
    only the activation live set tracks the plan; the idealized
    footprint (``client_costs.memory_bytes``) is what the paper's
    Fig. 5 prices and stays its own column. This is the prediction the
    measured ``memory_analysis`` peak is checked against
    (``MEMORY_FACTOR``)."""
    from repro.federated import comm
    from repro.roofline import client_costs as cc

    state = cc.build_ssl_param_tree(cfg, ssl)
    online_b = comm.tree_bytes(state["online"])
    state_b = comm.tree_bytes(state)
    enc_b = comm.tree_bytes(state["online"]["enc"])
    opt_b = 2 * online_b                       # AdamW m + v
    bs = train.batch_size
    batch_b = bs * 32 * 32 * 3 * 4
    c = cc.vit_costs(cfg, ssl)
    acts = (c.a_stem + (plan.sub_layers - plan.active_from) * c.a_block
            + c.a_heads) * bs * 4
    align_b = enc_b if plan.align else 0
    if engine_name == "sequential":
        args = state_b + opt_b + batch_b + align_b
        outs = state_b + opt_b
        peak = args + outs + acts
    else:
        # vmap round program: broadcast (state + server online + align
        # context) and per-client shards in; aggregated online + losses
        # out; each client's local state/opt/target copy and the wire
        # path live in temp space
        shard_b = clients * 2 * batch_b
        args = state_b + online_b + align_b + shard_b
        outs = online_b
        peak = args + outs + clients * (state_b + opt_b + acts + online_b)
    return {"argument_bytes": float(args), "output_bytes": float(outs),
            "peak_bytes": float(peak)}


# ---------------------------------------------------------------------------
# schedule measurement
# ---------------------------------------------------------------------------
def measure_schedule(schedule: str, engine_name: str, *, cfg=None, ssl=None,
                     train=None, rounds: int = 20, local_epochs: int = 3,
                     depth_dropout: float = 0.5, compile_memory: bool = True,
                     clients: int = 1, log=None) -> dict:
    """Measure one schedule on one engine at the measurement config.

    Lowers each *distinct* plan signature once for FLOPs; compiles only
    the signature the analytic model predicts as the schedule's memory
    peak (``compile_memory=False`` skips the compile and reports
    analytic-only memory). Returns measured and analytic columns side by
    side — totals use the ``schedule_costs`` accounting (per-sample x
    ``local_epochs``, summed over round plans; dense, see module
    docstring for the FLL+DD convention)."""
    from repro.configs.base import FLConfig
    from repro.core import schedule as sched
    from repro.federated import comm
    from repro.roofline import client_costs as cc

    if cfg is None or ssl is None or train is None:
        mcfg, mssl, mtrain = measurement_config()
        cfg, ssl, train = cfg or mcfg, ssl or mssl, train or mtrain
    fl = FLConfig(rounds=rounds, schedule=schedule, num_clients=2,
                  local_epochs=local_epochs, depth_dropout=depth_dropout)
    plans = sched.build_schedule(fl, cfg.num_layers)
    eng = _measurement_engine(engine_name, cfg, ssl, train, fl)

    costs = cc.vit_costs(cfg, ssl)
    params_bytes = comm.tree_bytes(
        cc.build_ssl_param_tree(cfg, ssl)["online"]["enc"])
    bs = train.batch_size
    denom = bs * (clients if engine_name == "vmap" else 1)

    sigs = {}
    for p in plans:
        sigs.setdefault(_plan_sig(p), p)
    stages, lowered = [], {}
    for sig, p in sigs.items():
        if log:
            log(f"[resources] lower {schedule}/{engine_name} "
                f"sub={p.sub_layers} act={p.active_from}")
        with unrolled_scans():
            low = eng.lower_round(p, clients=clients)
        lowered[sig] = low
        flops = float(cost_dict(low).get("flops", 0.0))
        stages.append({
            "sub_layers": p.sub_layers, "active_from": p.active_from,
            "align": bool(p.align), "depth_dropout": float(p.depth_dropout),
            "rounds": sum(1 for q in plans if _plan_sig(q) == sig),
            "flops_per_sample": flops / denom,
            "analytic_flops_per_sample":
                float(cc.flops_per_sample_round(costs, p)),
            "analytic_memory_bytes":
                float(cc.memory_bytes(costs, p, bs, params_bytes)),
        })

    peak_i = max(range(len(stages)),
                 key=lambda i: stages[i]["analytic_memory_bytes"])
    mem = None
    if compile_memory:
        peak_sig, peak_plan = list(sigs.items())[peak_i]
        if log:
            log(f"[resources] compile peak sig {schedule}/{engine_name} "
                f"sub={peak_sig[0]} act={peak_sig[1]}")
        # memory is measured on the ROLLED program — the artifact we
        # actually run. The unrolled lowering exists only for flops:
        # its buffer assignment keeps every unrolled layer's
        # activations live at once and inflates temp bytes by ~the
        # layer count.
        mem = memory_dict(eng.lower_round(peak_plan, clients=clients)
                          .compile())

    flops_total = sum(s["flops_per_sample"] * s["rounds"] * local_epochs
                      for s in stages)
    analytic_total = sum(
        s["analytic_flops_per_sample"] * s["rounds"] * local_epochs
        for s in stages)
    peak_plan = list(sigs.values())[peak_i]
    out = {
        "schedule": schedule, "engine": engine_name,
        "num_layers": cfg.num_layers, "batch_size": bs,
        "rounds": rounds, "local_epochs": local_epochs,
        "clients": clients,
        "stages": stages,
        "flops_total": flops_total,
        "analytic_flops_total": analytic_total,
        "analytic_peak_memory": stages[peak_i]["analytic_memory_bytes"],
        "program_peak_analytic": program_memory_analytic(
            cfg, ssl, train, peak_plan, engine_name,
            clients=clients)["peak_bytes"],
        "peak_memory": None, "argument_bytes": None,
        "output_bytes": None, "temp_bytes": None,
    }
    if mem is not None:
        out.update(peak_memory=float(mem["peak_bytes"]),
                   argument_bytes=float(mem["argument_bytes"]),
                   output_bytes=float(mem["output_bytes"]),
                   temp_bytes=float(mem["temp_bytes"]))
    return out
