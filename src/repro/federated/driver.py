"""End-to-end federated SSL driver (paper Algorithms 1 + 2).

Simulates the full FL process on one host: N clients with IID/Dirichlet
shards, per-round client sampling, local MoCo v3 (or SimCLR/BYOL) training
with the stage schedule, FedAvg aggregation, server-side calibration and
communication accounting. This is the reference implementation the
multi-pod launcher (``repro.launch.train``) distributes: there, the client
loop becomes a pjit'd program with clients mapped onto the mesh's data
axis, but the round/stage logic below is shared.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import schedule as sched
from repro.core import ssl as ssl_mod
from repro.federated import aggregate, client as client_mod, comm, server
from repro.optim import make_optimizer
from repro.optim.schedules import learning_rate, scaled_base_lr


@dataclass
class FLHistory:
    loss: List[float] = field(default_factory=list)
    round_stage: List[int] = field(default_factory=list)
    download_bytes: List[int] = field(default_factory=list)
    upload_bytes: List[int] = field(default_factory=list)

    @property
    def total_comm(self) -> int:
        return sum(self.download_bytes) + sum(self.upload_bytes)


def run_fedssl(model_cfg, ssl_cfg, fl, train_cfg, *, images, client_indices,
               aux_images=None, key=None, encoder=None, image_size: int = 32,
               log=None) -> tuple:
    """Run the FL process; returns (final_state, FLHistory).

    images: (n, H, W, 3) pooled training pool; client_indices: list of index
    arrays (one per client); aux_images: D_g for server calibration.
    """
    key = key if key is not None else jax.random.PRNGKey(fl.seed)
    if encoder is None:
        encoder = ssl_mod.make_vit_encoder(model_cfg, image_size)
    k_init, key = jax.random.split(key)
    state = ssl_mod.ssl_init(k_init, encoder, ssl_cfg)
    opt = make_optimizer(train_cfg)
    plans = sched.build_schedule(fl, encoder.num_stages)
    base_lr = scaled_base_lr(train_cfg.base_lr, train_cfg.batch_size)
    hist = FLHistory()
    counts = [len(ix) for ix in client_indices]

    step_cache: Dict[tuple, Any] = {}

    def get_step(plan):
        sig = (plan.sub_layers, plan.active_from, plan.align,
               plan.depth_dropout)
        if sig not in step_cache:
            step_cache[sig] = client_mod.make_local_step(
                encoder, ssl_cfg, opt, sub_layers=plan.sub_layers,
                active_from=plan.active_from, align=plan.align,
                depth_dropout=plan.depth_dropout)
        return step_cache[sig]

    calib_cache: Dict[int, Any] = {}

    def get_calib(sub_layers):
        if sub_layers not in calib_cache:
            calib_cache[sub_layers] = server.make_calibration_step(
                encoder, ssl_cfg, opt, sub_layers=sub_layers)
        return calib_cache[sub_layers]

    # stage-relative step counters for the cyclic LR strategy
    stage_start = {}
    for p in plans:
        stage_start.setdefault(p.stage, p.round_idx)
    stage_lengths = {s: sum(1 for p in plans if p.stage == s)
                     for s in set(p.stage for p in plans)}

    for plan in plans:
        if plan.new_stage:
            state = server.begin_stage(
                state, plan.stage, weight_transfer=fl.weight_transfer)
        lr = float(learning_rate(
            plan.round_idx, fl.rounds, base_lr, train_cfg.lr_schedule,
            stage_step=plan.round_idx - stage_start[plan.stage],
            stage_total=stage_lengths[plan.stage],
            warmup_steps=train_cfg.warmup_steps))
        key, ks = jax.random.split(key)
        participants = server.sample_clients(ks, fl.num_clients,
                                             fl.clients_per_round)
        global_enc = (jax.tree.map(jnp.copy, state["online"]["enc"])
                      if plan.align else None)
        step_fn = get_step(plan)
        outs, losses = [], []
        for i in participants:
            key, kc = jax.random.split(key)
            online_i, m = client_mod.local_train(
                state, images[client_indices[i]], step_fn, opt,
                epochs=fl.local_epochs, batch_size=train_cfg.batch_size,
                key=kc, lr=lr, global_enc=global_enc)
            outs.append(online_i)
            losses.append(float(m["loss"]))
        w = aggregate.client_weights([counts[i] for i in participants])
        new_online = aggregate.fedavg(outs, w)
        state = {**state, "online": new_online}
        if plan.server_calibrate and aux_images is not None:
            key, kg = jax.random.split(key)
            state = server.server_calibrate(
                state, aux_images, get_calib(plan.sub_layers), opt,
                epochs=fl.server_epochs, batch_size=train_cfg.batch_size,
                key=kg, lr=lr)
        cb = comm.round_comm_bytes(state["online"], plan)
        hist.loss.append(sum(losses) / max(1, len(losses)))
        hist.round_stage.append(plan.stage)
        hist.download_bytes.append(cb["download"])
        hist.upload_bytes.append(cb["upload"])
        if log:
            log(f"round {plan.round_idx + 1}/{fl.rounds} stage {plan.stage} "
                f"loss {hist.loss[-1]:.4f} lr {lr:.2e} "
                f"down {cb['download'] / 1e6:.2f}MB up {cb['upload'] / 1e6:.2f}MB")
    return state, hist
