"""End-to-end federated SSL driver (paper Algorithms 1 + 2).

Simulates the full FL process on one host: N clients with IID/Dirichlet
shards, per-round client sampling, local MoCo v3 (or SimCLR/BYOL) training
with the stage schedule, FedAvg aggregation, server-side calibration and
communication accounting.

The per-round "train participants, aggregate" middle is delegated to an
execution engine (``repro.federated.engine``): ``sequential`` loops over
clients one at a time (the numerical reference), ``vmap`` stacks the
sampled clients on a leading axis and runs the whole round — local steps
and FedAvg — as one jit'd program. The stage schedule, LR, calibration and
comm-accounting logic here is shared by both engines unchanged.

Every download and upload routes through the wire transport
(``repro.federated.transport``): the round plan's stage payload is packed
into flat buffers, pushed through the configured compression codec, and
training/aggregation consume the *decoded* payloads, so codec error
propagates realistically. ``FLHistory`` records both the analytic byte
counts (``comm.round_comm_bytes``) and the measured wire bytes; with the
fp32 identity codec the two are equal and training is bit-identical to
handing pytrees around directly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.core import schedule as sched
from repro.core import ssl as ssl_mod
from repro.federated import comm, server
from repro.federated import engine as engine_mod
from repro.federated import transport as transport_mod
from repro.optim import make_optimizer
from repro.optim.schedules import learning_rate, scaled_base_lr


@dataclass
class FLHistory:
    loss: List[float] = field(default_factory=list)
    round_stage: List[int] = field(default_factory=list)
    # analytic per-client byte counts (leaf shapes x round plan, comm.py)
    download_bytes: List[int] = field(default_factory=list)
    upload_bytes: List[int] = field(default_factory=list)
    # measured per-client wire bytes: size of the arrays the transport
    # codec actually put on the wire this round
    wire_download_bytes: List[int] = field(default_factory=list)
    wire_upload_bytes: List[int] = field(default_factory=list)

    @property
    def total_comm(self) -> int:
        return sum(self.download_bytes) + sum(self.upload_bytes)

    @property
    def total_wire(self) -> int:
        return sum(self.wire_download_bytes) + sum(self.wire_upload_bytes)

    @property
    def compression_ratio(self) -> float:
        """Measured compression: analytic (uncompressed) bytes over wire
        bytes. 1.0 for the identity codec."""
        return self.total_comm / max(1, self.total_wire)


def run_fedssl(model_cfg, ssl_cfg, fl, train_cfg, *, images, client_indices,
               aux_images=None, key=None, encoder=None, image_size: int = 32,
               log=None, engine: str = "sequential",
               codec: str = "fp32") -> tuple:
    """Run the FL process; returns (final_state, FLHistory).

    images: (n, H, W, 3) pooled training pool; client_indices: list of index
    arrays (one per client); aux_images: D_g for server calibration;
    engine: "sequential" (reference) or "vmap" (one dispatch per round);
    codec: wire compression (transport.CODECS — fp32/fp16/bf16/int8/topk).
    """
    key = key if key is not None else jax.random.PRNGKey(fl.seed)
    if encoder is None:
        encoder = ssl_mod.make_vit_encoder(model_cfg, image_size)
    k_init, key = jax.random.split(key)
    state = ssl_mod.ssl_init(k_init, encoder, ssl_cfg)
    opt = make_optimizer(train_cfg)
    plans = sched.build_schedule(fl, encoder.num_stages)
    base_lr = scaled_base_lr(train_cfg.base_lr, train_cfg.batch_size)
    hist = FLHistory()

    wire = transport_mod.Transport(codec, include_heads=fl.include_heads)
    eng = engine_mod.make_engine(
        engine, encoder=encoder, ssl_cfg=ssl_cfg, opt=opt, fl=fl,
        train_cfg=train_cfg, images=images, client_indices=client_indices,
        transport=wire)

    calib_cache: Dict[int, Any] = {}

    def get_calib(sub_layers):
        if sub_layers not in calib_cache:
            calib_cache[sub_layers] = server.make_calibration_step(
                encoder, ssl_cfg, opt, sub_layers=sub_layers)
        return calib_cache[sub_layers]

    # stage-relative step counters for the cyclic LR strategy
    stage_start = {}
    for p in plans:
        stage_start.setdefault(p.stage, p.round_idx)
    stage_lengths = {s: sum(1 for p in plans if p.stage == s)
                     for s in set(p.stage for p in plans)}

    for plan in plans:
        if plan.new_stage:
            state = server.begin_stage(
                state, plan.stage, weight_transfer=fl.weight_transfer)
        lr = float(learning_rate(
            plan.round_idx, fl.rounds, base_lr, train_cfg.lr_schedule,
            stage_step=plan.round_idx - stage_start[plan.stage],
            stage_total=stage_lengths[plan.stage],
            warmup_steps=train_cfg.warmup_steps))
        key, ks = jax.random.split(key)
        participants = server.sample_clients(ks, fl.num_clients,
                                             fl.clients_per_round)
        # download direction: clients (and the alignment loss's global
        # model) see the wire-decoded broadcast, not the server pytree
        dstate, down = server.broadcast_download(state, plan, wire)
        global_enc = (jax.tree.map(jnp.copy, dstate["online"]["enc"])
                      if plan.align else None)
        # per-participant keys are split here, identically for both
        # engines, so the main RNG chain (and the calibration key below)
        # is engine-independent
        client_keys = []
        for _ in participants:
            key, kc = jax.random.split(key)
            client_keys.append(kc)
        new_online, losses, up = eng.run_round(
            dstate, plan, participants, client_keys, lr, global_enc,
            server_online=state["online"])
        state = {**state, "online": new_online}
        if plan.server_calibrate and aux_images is not None:
            key, kg = jax.random.split(key)
            state = server.server_calibrate(
                state, aux_images, get_calib(plan.sub_layers), opt,
                epochs=fl.server_epochs, batch_size=train_cfg.batch_size,
                key=kg, lr=lr)
        cb = comm.round_comm_bytes(state["online"], plan,
                                   include_heads=fl.include_heads)
        hist.loss.append(sum(losses) / max(1, len(losses)))
        hist.round_stage.append(plan.stage)
        hist.download_bytes.append(cb["download"])
        hist.upload_bytes.append(cb["upload"])
        hist.wire_download_bytes.append(down["wire_bytes"])
        hist.wire_upload_bytes.append(up["wire_bytes"])
        if log:
            log(f"round {plan.round_idx + 1}/{fl.rounds} stage {plan.stage} "
                f"loss {hist.loss[-1]:.4f} lr {lr:.2e} "
                f"down {cb['download'] / 1e6:.2f}MB "
                f"up {cb['upload'] / 1e6:.2f}MB "
                f"wire {(down['wire_bytes'] + up['wire_bytes']) / 1e6:.2f}MB")
    return state, hist
