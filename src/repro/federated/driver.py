"""End-to-end federated SSL driver (paper Algorithms 1 + 2).

Simulates the full FL process on one host: N clients with IID/Dirichlet
shards, per-round client sampling, local MoCo v3 (or SimCLR/BYOL) training
with the stage schedule, FedAvg aggregation, server-side calibration and
communication accounting.

The per-round "train participants, aggregate" middle is delegated to an
execution engine (``repro.federated.engine``): ``sequential`` loops over
clients one at a time (the numerical reference), ``vmap`` stacks the
sampled clients on a leading axis and runs the whole round — local steps
and FedAvg — as one jit'd program. The stage schedule, LR, calibration and
comm-accounting logic here is shared by both engines unchanged.

Every download and upload routes through the wire transport
(``repro.federated.transport``): the round plan's stage payload is packed
into flat buffers, pushed through the configured compression codec, and
training/aggregation consume the *decoded* payloads, so codec error
propagates realistically. ``FLHistory`` records both the analytic byte
counts (``comm.round_comm_bytes``) and the measured wire bytes; with the
fp32 identity codec the two are equal and training is bit-identical to
handing pytrees around directly.

Privacy (``repro.privacy``, off by default): pass ``privacy=
PrivacyConfig(...)`` for client-level DP-FedAvg — per-client update
clipping inside both engines' wire paths, one calibrated Gaussian draw on
the aggregate, RDP accounting into ``FLHistory.epsilon`` with an optional
hard ``epsilon_budget`` stop — and/or pairwise-mask secure aggregation,
which replaces the float FedAvg with a masked fixed-point sum at the
aggregation boundary (composing with every codec, schedule, engine and
round policy). See docs/privacy.md.

Observability (``repro.obs``, off by default): pass ``obs=make_obs(...)``
and every round becomes a span tree — ``run > round > {download,
local_train, calibrate}`` with engine/transport child spans — annotated
with the analytic and wire byte counts, loss, LR and participation, while
the metrics registry accumulates wire-byte counters, round-time
histograms and a jit-recompile counter read off the engine/transport
compile caches. The trace CLI (``python -m repro.launch.trace``)
regenerates the paper's comm tables from those spans alone. With the
default ``NOOP_OBS`` every hook is a no-op and training output is
bit-identical to the uninstrumented driver.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schedule as sched
from repro.core import ssl as ssl_mod
from repro.federated import aggregate, comm, server
from repro.federated import engine as engine_mod
from repro.federated import transport as transport_mod
from repro.obs import NOOP_OBS, format_round_line
from repro.obs import resources as obs_resources
from repro.privacy import PrivacyEngine, make_privacy
from repro.optim import make_optimizer
from repro.optim.schedules import learning_rate, scaled_base_lr

# v2 added the privacy fields (epsilon / clip_fraction /
# secure_agg_overhead_bytes); v1 dicts still load, the new fields default
# to empty lists
HISTORY_VERSION = 2
_COMPAT_VERSIONS = (1, 2)


@dataclass
class FLHistory:
    loss: List[float] = field(default_factory=list)
    round_stage: List[int] = field(default_factory=list)
    # analytic per-client byte counts (leaf shapes x round plan, comm.py)
    download_bytes: List[int] = field(default_factory=list)
    upload_bytes: List[int] = field(default_factory=list)
    # measured per-client wire bytes: size of the arrays the transport
    # codec actually put on the wire this round
    wire_download_bytes: List[int] = field(default_factory=list)
    wire_upload_bytes: List[int] = field(default_factory=list)
    # fleet-simulator accounting (populated only when a Simulation is
    # passed to run_fedssl; empty lists otherwise)
    round_wall_clock: List[float] = field(default_factory=list)
    device_seconds: List[float] = field(default_factory=list)
    energy_joules: List[float] = field(default_factory=list)
    dropped_clients: List[int] = field(default_factory=list)
    participants: List[tuple] = field(default_factory=list)
    # privacy accounting (populated only when run_fedssl gets privacy=...;
    # empty lists otherwise): cumulative (ε, δ) after each round, fraction
    # of participants whose update was clipped, per-client secure-agg wire
    # overhead in bytes
    epsilon: List[float] = field(default_factory=list)
    clip_fraction: List[float] = field(default_factory=list)
    secure_agg_overhead_bytes: List[int] = field(default_factory=list)

    @property
    def total_comm(self) -> int:
        return sum(self.download_bytes) + sum(self.upload_bytes)

    @property
    def total_wire(self) -> int:
        return sum(self.wire_download_bytes) + sum(self.wire_upload_bytes)

    @property
    def compression_ratio(self) -> float:
        """Measured compression: analytic (uncompressed) bytes over wire
        bytes. 1.0 for the identity codec; NaN when nothing has been on
        the wire yet (an empty history has no ratio, not a huge one)."""
        if self.total_wire == 0:
            return float("nan")
        return self.total_comm / self.total_wire

    @property
    def total_wall_clock(self) -> float:
        return sum(self.round_wall_clock)

    @property
    def total_device_seconds(self) -> float:
        return sum(self.device_seconds)

    @property
    def total_energy(self) -> float:
        return sum(self.energy_joules)

    @property
    def total_dropped(self) -> int:
        return sum(self.dropped_clients)

    def wall_clock_to_loss(self, target: float):
        """Cumulative simulated seconds until the round-mean loss first
        reaches ``target``; None if it never does (or no simulation ran)."""
        t = 0.0
        for wall, loss in zip(self.round_wall_clock, self.loss):
            t += wall
            if loss <= target:
                return t
        return None

    # -- JSON round-trip: the one serialization traces, benches and
    # -- checkpoints share (versioned, keyed by field name) ------------------
    def to_dict(self) -> Dict[str, Any]:
        fields: Dict[str, list] = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            fields[f.name] = ([list(t) for t in v]
                              if f.name == "participants" else list(v))
        return {"version": HISTORY_VERSION, "fields": fields}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FLHistory":
        if d.get("version") not in _COMPAT_VERSIONS:
            raise ValueError(f"unsupported FLHistory version "
                             f"{d.get('version')!r} "
                             f"(have {_COMPAT_VERSIONS})")
        known = {f.name for f in dataclasses.fields(cls)}
        kw = {}
        for name, vals in d.get("fields", {}).items():
            if name not in known:
                raise ValueError(f"unknown FLHistory field '{name}'")
            kw[name] = ([tuple(v) for v in vals]
                        if name == "participants" else list(vals))
        return cls(**kw)


def run_fedssl(model_cfg, ssl_cfg, fl, train_cfg, *, images, client_indices,
               aux_images=None, key=None, encoder=None, image_size: int = 32,
               log=None, engine: str = "sequential",
               codec: str = "fp32", transport_kernels: str = "xla",
               sim=None, obs=None, privacy=None) -> tuple:
    """Run the FL process; returns (final_state, FLHistory).

    images: (n, H, W, 3) pooled training pool; client_indices: list of index
    arrays (one per client); aux_images: D_g for server calibration;
    engine: "sequential" (reference) or "vmap" (one dispatch per round);
    codec: wire compression (transport.CODECS — fp32/fp16/bf16/int8/topk);
    transport_kernels: wire-path engine (transport.TRANSPORT_KERNELS) —
    "xla" (jit'd slice/concat reference) or "pallas" (fused pack/codec
    kernels; fp32/fp16/bf16 bit-identical, int8/topk within 1e-6);
    sim: optional ``simulation.Simulation`` (fleet + round policy). With
    ``sim=None`` — or the synchronous policy over a uniform fleet — the
    training numerics are bit-identical to the pre-simulator driver; other
    policies change who trains and how updates aggregate, and ``FLHistory``
    gains per-round wall-clock / device-seconds / energy / drop counts;
    obs: optional ``repro.obs.Observability`` (spans, metrics, profiler).
    Defaults to the no-op bundle — tracing never changes training numerics;
    privacy: optional ``repro.privacy.PrivacyConfig`` (or an existing
    ``PrivacyEngine``) — client-level DP-FedAvg clipping/noise, RDP
    accounting into ``FLHistory.epsilon`` (``--dp-epsilon-budget`` halts
    training when exceeded) and pairwise-mask secure aggregation. The
    privacy RNG is a dedicated stream folded off the run key, so DP-off
    runs are byte-identical to passing ``privacy=None``.
    """
    obs = obs if obs is not None else NOOP_OBS
    tracer, met = obs.tracer, obs.metrics
    key = key if key is not None else jax.random.PRNGKey(fl.seed)
    prv = make_privacy(privacy)
    k_privacy = PrivacyEngine.fork_stream(key) if prv is not None else None
    if encoder is None:
        encoder = ssl_mod.make_vit_encoder(model_cfg, image_size)
    k_init, key = jax.random.split(key)
    state = ssl_mod.ssl_init(k_init, encoder, ssl_cfg)
    opt = make_optimizer(train_cfg)
    plans = sched.build_schedule(fl, encoder.num_stages)
    base_lr = scaled_base_lr(train_cfg.base_lr, train_cfg.batch_size)
    hist = FLHistory()

    counts = [len(ix) for ix in client_indices]
    wire = transport_mod.Transport(codec, include_heads=fl.include_heads,
                                   kernels=transport_kernels, obs=obs,
                                   privacy=prv)
    eng = engine_mod.make_engine(
        engine, encoder=encoder, ssl_cfg=ssl_cfg, opt=opt, fl=fl,
        train_cfg=train_cfg, images=images, client_indices=client_indices,
        transport=wire, obs=obs)
    if sim is not None:
        sim.obs = obs
        # ViT patch grid prices the per-step FLOPs (4x4 patches)
        sim.prepare(model_cfg, num_stages=encoder.num_stages,
                    counts=[len(ix) for ix in client_indices],
                    batch=train_cfg.batch_size,
                    tokens=(image_size // 4) ** 2,
                    local_epochs=fl.local_epochs)

    calib_cache: Dict[int, Any] = {}

    def get_calib(sub_layers):
        if sub_layers not in calib_cache:
            calib_cache[sub_layers] = server.make_calibration_step(
                encoder, ssl_cfg, opt, sub_layers=sub_layers)
        return calib_cache[sub_layers]

    # stage-relative step counters for the cyclic LR strategy
    stage_start = {}
    for p in plans:
        stage_start.setdefault(p.stage, p.round_idx)
    stage_lengths = {s: sum(1 for p in plans if p.stage == s)
                     for s in set(p.stage for p in plans)}

    obs.start_profiler()
    jit_entries = 0
    run_span = tracer.span(
        "run", cat="fl", mode="fedssl", schedule=fl.schedule, engine=engine,
        codec=wire.codec.name, kernels=transport_kernels, rounds=fl.rounds,
        clients=fl.num_clients, sim=sim.policy.name if sim else None)
    with run_span:
        for plan in plans:
            host_t0 = time.perf_counter()
            round_span = tracer.span("round", cat="fl",
                                     round=plan.round_idx, stage=plan.stage)
            with round_span:
                if plan.new_stage:
                    tracer.instant("stage_transition", cat="fl",
                                   stage=plan.stage)
                    if sim is not None:
                        sim.begin_stage()
                    state = server.begin_stage(
                        state, plan.stage,
                        weight_transfer=fl.weight_transfer)
                    if obs.measure_resources:
                        # measured cost attribution for the stage's round
                        # program (AOT lowering only — never compiles, so
                        # the jit.recompiles counter stays untouched)
                        with tracer.span("resources.measure", cat="obs",
                                         stage=plan.stage):
                            round_span.set(**obs_resources.stage_cost_attrs(
                                eng, plan))
                lr = float(learning_rate(
                    plan.round_idx, fl.rounds, base_lr,
                    train_cfg.lr_schedule,
                    stage_step=plan.round_idx - stage_start[plan.stage],
                    stage_total=stage_lengths[plan.stage],
                    warmup_steps=train_cfg.warmup_steps))
                key, ks = jax.random.split(key)
                # with the default overcommit (1.0) this is byte-for-byte
                # the historical sampling call — same key, same cohort
                cohort = server.sample_clients(
                    ks, fl.num_clients, fl.clients_per_round,
                    overcommit=sim.overcommit if sim is not None else 1.0)
                # download direction: clients (and the alignment loss's
                # global model) see the wire-decoded broadcast, not the
                # server pytree
                with tracer.span("download", cat="fl"):
                    dstate, down = server.broadcast_download(state, plan,
                                                             wire)
                global_enc = (jax.tree.map(jnp.copy,
                                           dstate["online"]["enc"])
                              if plan.align else None)
                outcome = None
                up_spec = (wire.plan_specs(state["online"], plan)["upload"]
                           if (sim is not None or prv is not None) else None)
                if sim is not None:
                    outcome = sim.begin_round(
                        plan, cohort, down_bytes=down["wire_bytes"],
                        up_bytes=wire.upload_stats(up_spec)["wire_bytes"])
                    participants = list(outcome.train_ids)
                else:
                    participants = cohort
                # privacy RNG: dedicated stream, folded per round — never
                # touches the main chain split above/below
                if prv is not None:
                    k_noise, mask_seed = PrivacyEngine.round_keys(
                        k_privacy, plan.round_idx)
                secure = prv is not None and prv.cfg.secure_agg
                # per-participant keys are split here, identically for
                # both engines, so the main RNG chain (and the calibration
                # key below) is engine-independent
                client_keys = []
                for _ in participants:
                    key, kc = jax.random.split(key)
                    client_keys.append(kc)
                train_span = tracer.span("local_train", cat="fl",
                                         participants=len(participants))
                if sim is not None and sim.policy.needs_client_trees:
                    # buffered-async: the engine returns per-client decoded
                    # trees; the policy buffers them and aggregates
                    # arrivals staleness-weighted (possibly rounds after
                    # they trained). Secure aggregation injects its masked
                    # FedAvg into the buffer flush (masks derived over each
                    # flush's arrival set — survivor-set re-masking).
                    with train_span:
                        if participants:
                            trees, losses, up = eng.run_round(
                                dstate, plan, participants, client_keys,
                                lr, global_enc,
                                server_online=state["online"],
                                collect=True)
                        else:  # every sampled candidate was busy/offline
                            trees, losses = [], []
                            up = wire.upload_stats(up_spec)
                    new_online, outcome = sim.complete_round_async(
                        outcome, trees,
                        agg_fn=prv.make_secure_agg_fn(
                            wire, up_spec, state["online"], mask_seed)
                        if secure else None)
                elif secure:
                    # synchronous/deadline secure round: collect decoded
                    # per-client trees, FedAvg through the masked
                    # fixed-point pipeline instead of the engine's fused
                    # float aggregation
                    with train_span:
                        trees, losses, up = eng.run_round(
                            dstate, plan, participants, client_keys, lr,
                            global_enc, server_online=state["online"],
                            collect=True)
                    w = aggregate.client_weights(
                        [counts[i] for i in participants])
                    new_online = prv.secure_fedavg(
                        trees, np.asarray(w), participants, spec=up_spec,
                        transport=wire, base=state["online"],
                        seed=mask_seed)
                    if sim is not None:
                        outcome = sim.complete_round(outcome)
                else:
                    with train_span:
                        new_online, losses, up = eng.run_round(
                            dstate, plan, participants, client_keys, lr,
                            global_enc, server_online=state["online"])
                    if sim is not None:
                        outcome = sim.complete_round(outcome)
                if prv is not None and prv.noise_enabled:
                    # one server-side Gaussian draw on the aggregated
                    # payload, σ = z·C·max_w (sensitivity of the weighted
                    # mean); the async policy reports its staleness
                    # weights, every other path is sample-count FedAvg
                    if outcome is not None and outcome.weights:
                        max_w = max(outcome.weights)
                    else:
                        agg_ids = (list(outcome.aggregated)
                                   if outcome is not None else participants)
                        max_w = float(np.max(np.asarray(
                            aggregate.client_weights(
                                [counts[i] for i in agg_ids]))))
                    new_online = prv.add_noise(new_online, up_spec, wire,
                                               k_noise, prv.sigma(max_w))
                state = {**state, "online": new_online}
                if plan.server_calibrate and aux_images is not None:
                    key, kg = jax.random.split(key)
                    with tracer.span("calibrate", cat="fl",
                                     sub_layers=plan.sub_layers):
                        state = server.server_calibrate(
                            state, aux_images, get_calib(plan.sub_layers),
                            opt, epochs=fl.server_epochs,
                            batch_size=train_cfg.batch_size, key=kg, lr=lr)
                cb = comm.round_comm_bytes(state["online"], plan,
                                           include_heads=fl.include_heads)
                if losses:
                    hist.loss.append(sum(losses) / len(losses))
                else:  # async round with no launches: carry the mean fwd
                    hist.loss.append(hist.loss[-1] if hist.loss
                                     else float("nan"))
                hist.round_stage.append(plan.stage)
                hist.download_bytes.append(cb["download"])
                hist.upload_bytes.append(cb["upload"])
                hist.wire_download_bytes.append(down["wire_bytes"])
                hist.wire_upload_bytes.append(up["wire_bytes"])
                sim_log = ""
                if outcome is not None:
                    hist.round_wall_clock.append(outcome.wall_clock_s)
                    hist.device_seconds.append(outcome.device_seconds)
                    hist.energy_joules.append(outcome.energy_j)
                    hist.dropped_clients.append(len(outcome.dropped))
                    hist.participants.append(tuple(participants))
                    sim_log = (f" sim {outcome.wall_clock_s:.1f}s "
                               f"dropped {len(outcome.dropped)}")
                eps = None
                if prv is not None:
                    # account the *sampled* cohort (Poisson-style q =
                    # cohort / population), not the survivor set — dropped
                    # clients were still contacted
                    prv.accountant.observe_round(
                        len(cohort) / max(1, fl.num_clients))
                    eps = float(prv.accountant.epsilon(prv.cfg.delta))
                    hist.epsilon.append(eps)
                    hist.clip_fraction.append(
                        float(up.get("clip_fraction", 0.0)))
                    hist.secure_agg_overhead_bytes.append(
                        prv.secure_overhead_bytes(up_spec,
                                                  wire.wire_bytes(up_spec)))
                    sim_log += (f" eps {eps:.3g}" if prv.dp else "")
                round_span.set(
                    loss=hist.loss[-1], lr=lr,
                    download_bytes=cb["download"],
                    upload_bytes=cb["upload"],
                    wire_download_bytes=down["wire_bytes"],
                    wire_upload_bytes=up["wire_bytes"],
                    participants=len(participants),
                    dropped=len(outcome.dropped) if outcome else 0)
                if obs.enabled:
                    # live watermark (mem.* attrs are excluded from
                    # Tracer.structure(): environment, not structure)
                    round_span.set(**obs_resources.memory_span_attrs())
                if prv is not None:
                    round_span.set(
                        epsilon=eps,
                        clip_fraction=hist.clip_fraction[-1],
                        secure_agg_overhead_bytes=hist
                        .secure_agg_overhead_bytes[-1])
            round_recompiles = 0
            if obs.enabled:
                met.counter("fl.rounds").inc()
                met.counter("comm.download_bytes").inc(cb["download"])
                met.counter("comm.upload_bytes").inc(cb["upload"])
                met.counter("wire.download_bytes").inc(down["wire_bytes"])
                met.counter("wire.upload_bytes").inc(up["wire_bytes"])
                met.histogram("round.host_seconds").observe(
                    time.perf_counter() - host_t0)
                met.histogram("round.loss").observe(hist.loss[-1])
                if outcome is not None:
                    met.histogram("sim.round_wall_clock_s").observe(
                        outcome.wall_clock_s)
                    met.counter("sim.energy_j").inc(outcome.energy_j)
                    met.counter("sim.dropped_clients").inc(
                        len(outcome.dropped))
                if prv is not None:
                    met.gauge("privacy.epsilon").set(eps)
                    met.histogram("privacy.clip_fraction").observe(
                        hist.clip_fraction[-1])
                    met.counter("privacy.secure_agg_overhead_bytes").inc(
                        hist.secure_agg_overhead_bytes[-1])
                entries = (eng.compile_cache_size()
                           + wire.compile_cache_size())
                if entries > jit_entries:
                    round_recompiles = entries - jit_entries
                    met.counter("jit.recompiles").inc(round_recompiles)
                    jit_entries = entries
                met.gauge("jit.cache_entries").set(jit_entries)
            if log:
                log(format_round_line(
                    plan.round_idx, fl.rounds, plan.stage, hist.loss[-1],
                    lr=lr, down_mb=cb["download"] / 1e6,
                    up_mb=cb["upload"] / 1e6,
                    wire_mb=(down["wire_bytes"] + up["wire_bytes"]) / 1e6,
                    extra=sim_log))
            if obs.health is not None:
                ratio = ((cb["download"] + cb["upload"])
                         / max(1, down["wire_bytes"] + up["wire_bytes"]))
                for alert in obs.health.observe_round(
                        plan.round_idx, loss=hist.loss[-1],
                        compression_ratio=ratio,
                        dropped=len(outcome.dropped) if outcome else 0,
                        participants=len(participants),
                        recompiles=round_recompiles,
                        new_stage=plan.new_stage):
                    tracer.instant(
                        "health." + alert.kind, cat="health",
                        level=alert.level, round=plan.round_idx,
                        value=(float(alert.value)
                               if np.isfinite(alert.value) else None),
                        message=alert.message)
                    if log:
                        log(f"health[{alert.level}] round "
                            f"{plan.round_idx}: {alert.message}")
                if obs.health.should_halt:
                    tracer.instant("health.halt", cat="health",
                                   round=plan.round_idx)
                    if log:
                        log(f"health: fatal alert; halting after round "
                            f"{plan.round_idx + 1}/{fl.rounds}")
                    break
            if (prv is not None and prv.cfg.epsilon_budget > 0.0
                    and eps > prv.cfg.epsilon_budget):
                tracer.instant("privacy.budget_exhausted", cat="fl",
                               round=plan.round_idx, epsilon=eps,
                               budget=prv.cfg.epsilon_budget)
                if log:
                    log(f"privacy budget exhausted: eps {eps:.4g} > "
                        f"{prv.cfg.epsilon_budget:.4g} after round "
                        f"{plan.round_idx + 1}/{fl.rounds}; halting")
                break
    if obs.enabled:
        met.gauge("wire.compression_ratio").set(hist.compression_ratio)
    obs.stop_profiler()
    return state, hist
