"""FedAvg aggregation (paper Fig. 1, step iv).

``fedavg`` is a jit'd weighted average over a list of client pytrees.

Layer-wise semantics note: clients only ever *change* the active stage's
blocks and the MLP heads (frozen blocks receive masked zero updates), so
averaging the full tree is mathematically identical to exchanging only the
active layer — frozen entries are equal across clients. Communication-cost
accounting (``repro.federated.comm``) instead follows the per-round plan's
download/upload stage ranges, exactly like a real deployment would.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=())
def fedavg(client_trees, weights):
    """client_trees: list of pytrees; weights: (N,) fp32 summing to 1."""
    def avg(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        w = weights.reshape((-1,) + (1,) * (stacked.ndim - 1))
        return jnp.sum(stacked * w, axis=0).astype(leaves[0].dtype)

    return jax.tree.map(avg, *client_trees)


def fedavg_stacked(stacked, weights):
    """FedAvg over client-stacked pytrees (leading axis = client).

    Same weighted mean as ``fedavg`` but over one stacked tree instead of a
    list — the form the vectorized engine produces, so aggregation fuses
    into the round's single compiled program.
    """
    def avg(a):
        w = weights.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.sum(a.astype(jnp.float32) * w, axis=0).astype(a.dtype)

    return jax.tree.map(avg, stacked)


def client_weights(sample_counts):
    w = jnp.asarray(sample_counts, jnp.float32)
    return w / jnp.sum(w)
