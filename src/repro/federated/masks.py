"""Per-stage optimizer update masks for layer-wise / progressive training.

The forward pass blocks gradients into frozen layers (``stop_gradient``),
so frozen grads are exactly zero — but decoupled weight decay would still
shrink frozen weights. These masks zero the *whole* update outside the
active range, preserving layer-wise semantics bit-exactly.

Stacked block leaves (leading dim = stage axis) get per-stage vector masks;
embedding-side leaves are active only when the prefix is unfrozen
(``active_from == 0``); heads / final norm / shared (Zamba) blocks are
always active.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.federated.leaves import classify_leaf


def stage_update_mask(params, sub_layers: int, active_from: int):
    """Mask pytree matching ``params``: 1.0 = update, 0.0 = frozen."""
    def leaf_mask(path, a):
        kind = classify_leaf(path)
        if kind == "stacked":
            n = a.shape[0]
            idx = jnp.arange(n)
            m = ((idx >= active_from) & (idx < sub_layers)).astype(jnp.float32)
            return m.reshape((n,) + (1,) * (a.ndim - 1))
        if kind == "embed":
            return jnp.float32(1.0 if active_from == 0 else 0.0)
        return jnp.float32(1.0)   # heads, final_ln, shared_attn, conv stubs

    return jax.tree_util.tree_map_with_path(leaf_mask, params)
