"""Server-side mechanisms: calibration (paper Algorithm 1 line 7) and the
round bookkeeping (stage transitions, weight transfer, client sampling).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import schedule as sched
from repro.core import ssl as ssl_mod
from repro.data.augment import two_views
from repro.federated.masks import stage_update_mask


def make_calibration_step(encoder, ssl_cfg, opt, *, sub_layers: int):
    """End-to-end SSL step over the current sub-model (active_from=0)."""
    @jax.jit
    def step(state, opt_state, images, key, lr):
        x1, x2 = two_views(key, images)

        def loss_fn(online):
            st = {**state, "online": online}
            return ssl_mod.ssl_loss(st, x1, x2, encoder, ssl_cfg,
                                    sub_layers=sub_layers, active_from=0)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["online"])
        mask = stage_update_mask(state["online"], sub_layers, 0)
        new_online, opt_state = opt.update(grads, opt_state,
                                           state["online"], lr, mask)
        state = {**state, "online": new_online}
        state = ssl_mod.momentum_update(state, ssl_cfg.momentum)
        return state, opt_state, metrics

    return step


def server_calibrate(state, aux_images, step_fn, opt, *, epochs: int,
                     batch_size: int, key, lr):
    """Train the aggregated sub-model end-to-end on D_g (Algorithm 1 l.7).

    Uses the server's own optimizer state (fresh per round, like clients).
    """
    opt_state = opt.init(state["online"])
    n = aux_images.shape[0]
    bs = min(batch_size, n)
    for e in range(epochs):
        key, kp = jax.random.split(key)
        perm = jax.random.permutation(kp, n)
        for b in range(n // bs):
            key, kb = jax.random.split(key)
            sel = jax.lax.dynamic_slice_in_dim(perm, b * bs, bs)
            state, opt_state, _ = step_fn(state, opt_state,
                                          aux_images[sel], kb, lr)
    return state


def broadcast_download(state, plan, transport):
    """Server -> clients (paper Fig. 1 step i): push the round plan's
    download payload over the wire transport and return the state clients
    actually train from, plus measured wire stats.

    With the identity codec the returned tree is bit-identical to
    ``state``; with a lossy codec the decoded download is what every client
    (and the alignment loss's global model) sees, so wire compression error
    reaches local training exactly as it would in a real deployment. Leaves
    outside the payload keep the server values — they stand in for the
    client's cached copy from earlier rounds, which the plan says is still
    current.
    """
    view, stats = transport.broadcast(state["online"], plan)
    return {**state, "online": view}, stats


def begin_stage(state, stage: int, *, weight_transfer: bool):
    """Stage-transition housekeeping: L_{s-1} -> L_s weight transfer."""
    if not weight_transfer or stage < 2:
        return state
    online = dict(state["online"])
    online["enc"] = sched.transfer_model(online["enc"], None, stage)
    out = {**state, "online": online}
    if "target" in state:
        out["target"] = {
            "enc": sched.transfer_model(dict(state["target"]["enc"]), None,
                                        stage),
            "proj": state["target"]["proj"],
        }
    return out


def sample_clients(key, num_clients: int, clients_per_round: int, *,
                   overcommit: float = 1.0):
    """Sample the round's cohort. ``overcommit > 1`` (the deadline
    policy's straggler insurance) inflates the sample by that factor,
    clamped to the population; ``overcommit=1`` is byte-for-byte the
    historical behavior (same key, same draw)."""
    n = clients_per_round or num_clients
    n = min(num_clients, math.ceil(n * overcommit))
    if n >= num_clients:
        return list(range(num_clients))
    idx = jax.random.choice(key, num_clients, (n,), replace=False)
    return [int(i) for i in idx]
