"""Evaluation protocols: linear evaluation and fine-tuning (paper Sec 5.1).

Linear evaluation: heads are discarded; a linear classifier is trained on
the frozen encoder's representations. Fine-tuning additionally unfreezes
the encoder.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.init import dense_init
from repro.optim import make_optimizer
from repro.optim.schedules import learning_rate


def extract_features(encoder, enc_params, images, batch_size: int = 256):
    feats = []
    fwd = jax.jit(lambda x: encoder.apply(enc_params, x))
    n = (images.shape[0] // batch_size) * batch_size
    for i in range(0, max(n, batch_size), batch_size):
        xb = images[i:i + batch_size]
        if xb.shape[0] == 0:
            break
        feats.append(fwd(xb))
    return jnp.concatenate(feats, axis=0)


def linear_eval(encoder, enc_params, train_images, train_labels,
                test_images, test_labels, *, num_classes: int,
                epochs: int = 20, batch_size: int = 256, lr: float = 3e-2,
                train_cfg=None, key=None):
    """Returns test accuracy of a linear probe on frozen features."""
    key = key if key is not None else jax.random.PRNGKey(0)
    n_train = (train_images.shape[0] // batch_size) * batch_size
    f_train = extract_features(encoder, enc_params, train_images, batch_size)
    f_test = extract_features(encoder, enc_params, test_images, batch_size)
    y_train = train_labels[:f_train.shape[0]]
    y_test = test_labels[:f_test.shape[0]]
    d = f_train.shape[-1]
    from repro.configs.base import TrainConfig
    tc = train_cfg or TrainConfig(optimizer="adamw", base_lr=lr,
                                  weight_decay=1e-5)
    opt = make_optimizer(tc)
    params = {"w": dense_init(key, (d, num_classes), jnp.float32),
              "b": jnp.zeros((num_classes,), jnp.float32)}
    opt_state = opt.init(params)
    total_steps = epochs * max(1, n_train // batch_size)

    @jax.jit
    def step(params, opt_state, xb, yb, lr_now):
        def loss_fn(p):
            logits = xb @ p["w"] + p["b"]
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yb[:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params, lr_now)
        return params, opt_state, loss

    t = 0
    for e in range(epochs):
        key, kp = jax.random.split(key)
        perm = jax.random.permutation(kp, f_train.shape[0])
        for b in range(f_train.shape[0] // batch_size):
            sel = perm[b * batch_size:(b + 1) * batch_size]
            lr_now = float(learning_rate(t, total_steps, lr, "cosine"))
            params, opt_state, _ = step(params, opt_state, f_train[sel],
                                        y_train[sel], lr_now)
            t += 1
    logits = f_test @ params["w"] + params["b"]
    acc = jnp.mean((jnp.argmax(logits, -1) == y_test).astype(jnp.float32))
    return float(acc)
