"""Wire-level transport: real stage payloads + pluggable compression codecs.

The analytic accounting in ``repro.federated.comm`` *predicts* how many
bytes a round moves; this module actually moves them. A round plan's stage
range is sliced out of every stacked/embed/head leaf into one flat
contiguous fp32 buffer (``pack_stage_payload``), pushed through a codec
(cast, quantize or sparsify — ``encode``/``decode``), and scattered back
into a model tree (``unpack_stage_payload``). Both directions of the FL
loop route through here:

  download   server tree -> payload -> wire -> decoded payload -> the tree
             clients actually train from (codec error reaches training).
  upload     each client's trained tree -> payload -> wire (per-client
             error-feedback residual for sparsifying codecs) -> decoded
             payload -> reassembled client tree; FedAvg then consumes the
             *decoded* trees, never the in-memory originals.

Codecs (``make_codec``):

  fp32        identity. Training is bit-identical to handing pytrees
              around directly, and wire bytes equal the analytic
              ``comm.round_comm_bytes`` numbers exactly.
  fp16/bf16   cast-on-the-wire, 2x compression.
  int8        per-channel symmetric quantization (scale = amax/127 over
              the last axis' channels; per-tensor for vectors) with fp32
              dequant scales on the wire, ~3.9x.
  topk[:f]    magnitude top-k sparsification keeping fraction ``f``
              (default 0.1) of entries as (int32 index, fp32 value)
              pairs. Sparsifies *deltas against a reference both ends
              hold* (uploads: the downloaded model, with per-client
              error-feedback residuals carried across rounds — Seide et
              al. 2014 / Karimireddy et al. 2019; downloads: a
              server-side mirror of the clients' copy, densely re-synced
              whenever the payload layout changes), so dropped mass is
              delayed, never lost.

Payload membership (which leaves travel, per direction) is the shared
``classify_leaf``/``comm.plan_payloads`` contract, so measured and analytic
bytes count the same tensors. All pack/encode/decode/unpack functions are
pure JAX: the vectorized engine vmaps them over the client axis inside its
single jit'd round program. See docs/transport.md.

Two wire-path engines (``kernels=`` / ``--transport-kernels``):

  xla       the legacy leaf-by-leaf slice/cast/concat path above.
  pallas    the fused kernels in ``repro.kernels`` (slot-table
            gather/scatter, fused int8 quant, top-k with on-chip
            error-feedback) for the host-called wire functions —
            ``_pack_fn`` / ``_upload_fn`` / ``_bcast_fn`` /
            ``_bcast_delta_fn`` — used by the driver broadcast, the
            sequential engine and the fleet simulator. The vmap engine's
            in-program ``make_wire_transform`` intentionally stays XLA:
            it is fused into that engine's single jit'd round program,
            which this flag must not touch. See docs/kernels.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated import aggregate
from repro.federated.leaves import classify_leaf, path_keys
from repro.kernels import hostwire
from repro.kernels import ops as kops
from repro.obs import NOOP_OBS

WIRE_DTYPE = jnp.float32          # payload element dtype before encoding
CODECS = ("fp32", "fp16", "bf16", "int8", "topk")
TRANSPORT_KERNELS = ("xla", "pallas")


# ---------------------------------------------------------------------------
# payload spec: which pieces of the tree travel, and where they land in the
# flat buffer — static per (tree shapes, stage range, include flags)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LeafSlot:
    path: Tuple[str, ...]     # key path into the params tree
    kind: str                 # stacked | embed | head | extra
    lo: int                   # stacked: first stage row shipped
    hi: int                   # stacked: one past the last stage row
    shape: Tuple[int, ...]    # shape of the shipped piece
    offset: int               # start element in the flat payload
    size: int                 # element count of the shipped piece


@dataclass(frozen=True)
class PayloadSpec:
    slots: Tuple[LeafSlot, ...]
    total: int                # flat payload length in elements
    sig: Tuple                # hashable identity (for caches / residuals)

    @property
    def payload_bytes(self) -> int:
        """Uncompressed (fp32) payload size — the codec-free baseline."""
        return self.total * jnp.dtype(WIRE_DTYPE).itemsize


def tree_signature(params) -> Tuple:
    """Hashable (path, shape, dtype) fingerprint of a params tree."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    return tuple((path_keys(p), tuple(a.shape), str(a.dtype))
                 for p, a in flat)


def build_payload_spec(params, stage_range, *, include_embed: bool,
                       include_heads: bool) -> PayloadSpec:
    """Walk ``params`` (concrete or ``eval_shape`` abstract) and lay out the
    payload: stacked leaves contribute their ``[lo, hi)`` stage rows, embed
    and head leaves contribute whole tensors per the flags, extra leaves
    (final norm, shared blocks) always travel."""
    lo_req, hi_req = int(stage_range[0]), int(stage_range[1])
    slots: List[LeafSlot] = []
    offset = 0
    for path, a in jax.tree_util.tree_flatten_with_path(params)[0]:
        kind = classify_leaf(path)
        if kind == "stacked":
            lo, hi = max(0, lo_req), min(a.shape[0], hi_req)
            if hi <= lo:
                continue
            shape = (hi - lo,) + tuple(a.shape[1:])
        elif (kind == "embed" and not include_embed) or \
                (kind == "head" and not include_heads):
            continue
        else:
            lo, hi = 0, 0
            shape = tuple(a.shape)
        size = int(np.prod(shape))
        slots.append(LeafSlot(path_keys(path), kind, lo, hi, shape,
                              offset, size))
        offset += size
    sig = (tuple((s.path, s.lo, s.hi, s.shape) for s in slots), offset)
    return PayloadSpec(tuple(slots), offset, sig)


def pack_stage_payload(params, spec: PayloadSpec):
    """Slice the spec'd pieces out of ``params`` into one flat fp32 buffer."""
    by_path = {path_keys(p): a
               for p, a in jax.tree_util.tree_flatten_with_path(params)[0]}
    parts = []
    for s in spec.slots:
        a = by_path[s.path]
        if s.kind == "stacked":
            a = a[s.lo:s.hi]
        parts.append(a.astype(WIRE_DTYPE).ravel())
    if not parts:
        return jnp.zeros((0,), WIRE_DTYPE)
    return jnp.concatenate(parts)


def unpack_stage_payload(base, flat, spec: PayloadSpec):
    """Scatter a flat payload back into ``base``: stacked rows are written
    into their stage range, whole-tensor slots replace the base leaf, and
    leaves outside the spec keep the base value (the receiver's own copy —
    the server's model for uploads, the client's cached prefix for
    downloads)."""
    by_path = {s.path: s for s in spec.slots}

    def leaf(path, a):
        s = by_path.get(path_keys(path))
        if s is None:
            return a
        seg = jax.lax.dynamic_slice_in_dim(flat, s.offset, s.size)
        seg = seg.reshape(s.shape).astype(a.dtype)
        if s.kind == "stacked":
            return a.at[s.lo:s.hi].set(seg)
        return seg

    return jax.tree_util.tree_map_with_path(leaf, base)


# ---------------------------------------------------------------------------
# codecs: pure-JAX encode/decode over the flat payload
# ---------------------------------------------------------------------------
class Fp32Codec:
    """Identity codec — the uncompressed reference wire format."""

    name = "fp32"
    error_feedback = False
    delta = False

    def encode(self, flat, spec):
        return {"q": flat}

    def decode(self, wire, spec):
        return wire["q"]


class CastCodec:
    """Cast-on-the-wire: fp16 or bf16 payload, decoded back to fp32."""

    error_feedback = False
    delta = False

    def __init__(self, name: str):
        self.name = name
        self.dtype = jnp.float16 if name == "fp16" else jnp.bfloat16

    def encode(self, flat, spec):
        return {"q": flat.astype(self.dtype)}

    def decode(self, wire, spec):
        return wire["q"].astype(WIRE_DTYPE)


def _int8_channels(slot: LeafSlot) -> int:
    """Channels of a slot for per-channel scales: the last axis when the
    slot is a proper matrix/stack (>= 4 rows), else one per-tensor scale."""
    if len(slot.shape) >= 2:
        ch = slot.shape[-1]
        if slot.size // max(1, ch) >= 4:
            return ch
    return 1


class Int8Codec:
    """Symmetric per-channel int8: q = round(x / s), s = amax_channel/127.

    The wire carries the int8 payload plus one fp32 dequant scale per
    channel (per tensor for vectors), ~3.9x smaller than fp32."""

    name = "int8"
    error_feedback = False
    delta = False

    def encode(self, flat, spec):
        qs, scales = [], []
        for s in spec.slots:
            seg = jax.lax.dynamic_slice_in_dim(flat, s.offset, s.size)
            ch = _int8_channels(s)
            seg2 = seg.reshape(-1, ch)
            amax = jnp.max(jnp.abs(seg2), axis=0)
            scale = jnp.maximum(amax, 1e-12) / 127.0
            q = jnp.clip(jnp.round(seg2 / scale), -127, 127).astype(jnp.int8)
            qs.append(q.ravel())
            scales.append(scale)
        return {"q": jnp.concatenate(qs), "scale": jnp.concatenate(scales)}

    def decode(self, wire, spec):
        outs, so = [], 0
        for s in spec.slots:
            ch = _int8_channels(s)
            q = jax.lax.dynamic_slice_in_dim(wire["q"], s.offset, s.size)
            scale = jax.lax.dynamic_slice_in_dim(wire["scale"], so, ch)
            so += ch
            outs.append((q.reshape(-1, ch).astype(WIRE_DTYPE)
                         * scale).ravel())
        return jnp.concatenate(outs)


class TopKCodec:
    """Magnitude top-k sparsification of *deltas*, with error feedback.

    Keeps the ``fraction`` largest-|x| entries as (int32 index, fp32 value)
    pairs. Unlike the cast/quantize codecs, top-k is meaningless on raw
    weights (dropping 90% of a model's parameters destroys it), so
    ``delta=True`` makes the transport sparsify *differences against a
    reference both ends hold*: uploads ship (trained - downloaded), with
    ``error_feedback=True`` adding each client's previously dropped mass
    back into the next round's payload (Seide et al. 2014; Karimireddy et
    al. 2019); downloads ship (model - server-side mirror of what clients
    already hold), with a dense re-sync whenever the payload layout
    changes (stage transitions)."""

    error_feedback = True
    delta = True

    def __init__(self, fraction: float = 0.1):
        if not (0.0 < fraction <= 1.0):
            raise ValueError(f"topk fraction must be in (0, 1]: {fraction}")
        self.fraction = fraction
        self.name = f"topk:{fraction:g}"

    def k_for(self, spec: PayloadSpec) -> int:
        return max(1, min(spec.total, int(round(spec.total * self.fraction))))

    def encode(self, flat, spec):
        k = self.k_for(spec)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        return {"idx": idx.astype(jnp.int32), "val": flat[idx]}

    def decode(self, wire, spec):
        return jnp.zeros((spec.total,), WIRE_DTYPE).at[wire["idx"]].set(
            wire["val"])


def make_codec(name: str):
    """Codec registry. ``topk`` takes an optional fraction: ``topk:0.05``."""
    if name == "fp32":
        return Fp32Codec()
    if name in ("fp16", "bf16"):
        return CastCodec(name)
    if name == "int8":
        return Int8Codec()
    if name == "topk" or name.startswith("topk:"):
        frac = float(name.split(":", 1)[1]) if ":" in name else 0.1
        return TopKCodec(frac)
    raise ValueError(f"unknown codec '{name}'; one of {CODECS} "
                     f"(topk takes an optional fraction, e.g. topk:0.05)")


def wire_nbytes(wire_shapes) -> int:
    """Byte size of a wire message (a pytree of arrays / ShapeDtypeStructs)."""
    return int(sum(int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
                   for a in jax.tree.leaves(wire_shapes)))


# ---------------------------------------------------------------------------
# fused kernel wire path (kernels="pallas"): the PayloadSpec rendered as the
# static slot tables the repro.kernels wire kernels consume
# ---------------------------------------------------------------------------
def _slot_src_offset(slot: LeafSlot) -> int:
    """Element offset of the slot's range inside its raveled leaf: stacked
    slots start at row ``lo``, whole-tensor slots at 0."""
    if slot.kind != "stacked":
        return 0
    return slot.lo * (slot.size // (slot.hi - slot.lo))


def slot_pack_layout(spec: PayloadSpec) -> Tuple[Tuple[int, int, int], ...]:
    """((src_off, dst_off, size), ...) gather table for ``kops.wire_pack``."""
    return tuple((_slot_src_offset(s), s.offset, s.size) for s in spec.slots)


def int8_segs(spec: PayloadSpec) -> Tuple[Tuple, int]:
    """(((offset, size, channels, scale_offset), ...), n_scales) quant
    table for ``kops.wire_int8_encode/decode`` — channel choice shared
    with the XLA codec (``_int8_channels``)."""
    segs, soff = [], 0
    for s in spec.slots:
        ch = _int8_channels(s)
        segs.append((s.offset, s.size, ch, soff))
        soff += ch
    return tuple(segs), soff


def _slot_leaves(tree, spec: PayloadSpec):
    """Leaves of ``tree`` in slot order (payload membership only)."""
    by_path = {path_keys(p): a
               for p, a in jax.tree_util.tree_flatten_with_path(tree)[0]}
    return [by_path[s.path] for s in spec.slots]


def kernel_pack(tree, spec: PayloadSpec):
    """Fused-kernel ``pack_stage_payload``: one slot-table gather."""
    return kops.wire_pack(_slot_leaves(tree, spec), slot_pack_layout(spec),
                          spec.total)


def kernel_unpack(base, flat, spec: PayloadSpec):
    """Fused-kernel ``unpack_stage_payload``: one slot-table scatter over
    the base leaves; leaves outside the spec keep the base value."""
    with_paths, treedef = jax.tree_util.tree_flatten_with_path(base)
    by_path = {s.path: s for s in spec.slots}
    items = []                    # (leaf position, slot, base leaf)
    for i, (p, a) in enumerate(with_paths):
        s = by_path.get(path_keys(p))
        if s is not None:
            items.append((i, s, a))
    layout = tuple(
        (_slot_src_offset(s), s.offset, s.size,
         s.size == int(np.prod(a.shape))) for _, s, a in items)
    outs = kops.wire_unpack(flat, [a for _, _, a in items], layout)
    leaves = [a for _, a in with_paths]
    for (i, _, a), out in zip(items, outs):
        leaves[i] = out.reshape(a.shape)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _sparse_add(base_flat, idx, val, total: int):
    """base + scatter(idx, val) without materializing the dense decoded
    delta; numpy fast path when the kernel engine returned host arrays."""
    if isinstance(idx, np.ndarray):
        base = np.asarray(base_flat)
        out = hostwire.wire_buffer(total)
        np.copyto(out, base, casting="unsafe")
        out[idx] += val
        return out
    return jnp.asarray(base_flat, jnp.float32).at[idx].add(val)


def kernel_codec_fns(codec, spec: PayloadSpec):
    """(encode, decode) host-callable pair over the flat payload through
    the fused kernel wire path — the ``kernels="pallas"`` counterpart of
    ``codec.encode``/``codec.decode``, wire-format compatible (same dict
    keys/dtypes). Top-k is delta-only and handled by the delta wire
    functions (``kops.wire_topk_encode_ef``), not here; for bench/parity
    purposes this returns its non-delta form (sparsify the payload
    itself)."""
    name = codec.name
    if name == "fp32":
        return (lambda flat: {"q": flat}), (lambda wire: wire["q"])
    if name in ("fp16", "bf16"):
        dtype = codec.dtype
        return (lambda flat: {"q": kops.wire_cast_encode(flat, dtype)},
                lambda wire: kops.wire_cast_decode(wire["q"]))
    if name == "int8":
        segs, nscales = int8_segs(spec)

        def enc(flat):
            q, scales = kops.wire_int8_encode(flat, segs, nscales)
            return {"q": q, "scale": scales}

        def dec(wire):
            return kops.wire_int8_decode(wire["q"], wire["scale"], segs,
                                         spec.total)
        return enc, dec
    if name.startswith("topk"):
        k = codec.k_for(spec)

        def enc(flat):
            if isinstance(flat, np.ndarray):
                ref = hostwire.wire_buffer(flat.shape[0])
                ref.fill(0.0)
            else:
                ref = jnp.zeros_like(flat)
            idx, val, _ = kops.wire_topk_encode_ef(flat, ref, None, k)
            return {"idx": idx, "val": val}

        def dec(wire):
            return kops.wire_topk_decode(wire["idx"], wire["val"],
                                         spec.total)
        return enc, dec
    raise ValueError(f"no kernel codec path for '{name}'")


# ---------------------------------------------------------------------------
# transport: spec/program caches, residual store, measured byte accounting
# ---------------------------------------------------------------------------
class Transport:
    """One per FL run. Owns the codec, per-direction payload specs, the
    per-client error-feedback residuals, and the measured wire-byte stats
    the driver folds into ``FLHistory``."""

    def __init__(self, codec="fp32", *, include_heads: bool = True,
                 kernels: str = "xla", obs=None, privacy=None):
        if kernels not in TRANSPORT_KERNELS:
            raise ValueError(f"unknown transport kernels '{kernels}'; "
                             f"one of {TRANSPORT_KERNELS}")
        self.codec = make_codec(codec) if isinstance(codec, str) else codec
        self.include_heads = include_heads
        self.kernels = kernels
        # optional repro.privacy.PrivacyEngine: when clipping is on, every
        # upload's payload update is global-norm clipped before the codec
        # (DP-FedAvg step 1) on both wire engines
        self.privacy = privacy
        self.obs = obs if obs is not None else NOOP_OBS
        self._specs: Dict[Tuple, PayloadSpec] = {}
        self._wire_bytes: Dict[Tuple, int] = {}
        self._roundtrips: Dict[Tuple, object] = {}
        self._resid: Dict[Tuple, Tuple[Tuple, object]] = {}
        self._mirror: Optional[Tuple[Tuple, object]] = None

    def compile_cache_size(self) -> int:
        """Compiled-specialization count across the cached wire programs
        (the pallas-mode entries are plain host callables and count 0)."""
        from repro.federated.engine import jit_cache_entries
        return jit_cache_entries(self._roundtrips.values())

    # -- specs --------------------------------------------------------------
    def spec(self, params, stage_range, include_embed: bool) -> PayloadSpec:
        key = (tree_signature(params), (int(stage_range[0]),
                                        int(stage_range[1])),
               include_embed, self.include_heads)
        if key not in self._specs:
            self._specs[key] = build_payload_spec(
                params, stage_range, include_embed=include_embed,
                include_heads=self.include_heads)
        return self._specs[key]

    def plan_specs(self, params, plan) -> Dict[str, PayloadSpec]:
        """Download/upload payload specs for a RoundPlan — membership rules
        shared with the analytic accounting (``comm.plan_payloads``)."""
        from repro.federated import comm
        return {d: self.spec(params, rng, include_embed=emb)
                for d, (rng, emb) in comm.plan_payloads(plan).items()}

    def wire_bytes(self, spec: PayloadSpec) -> int:
        """Measured wire size: byte count of the arrays the codec actually
        emits for this payload (via ``eval_shape`` on the real encoder)."""
        key = (spec.sig,)
        if key not in self._wire_bytes:
            shapes = jax.eval_shape(
                lambda f: self.codec.encode(f, spec),
                jax.ShapeDtypeStruct((spec.total,), WIRE_DTYPE))
            self._wire_bytes[key] = wire_nbytes(shapes)
        return self._wire_bytes[key]

    # -- the wire round-trip ------------------------------------------------
    def _upload_one(self, out, base, ref_flat, res, spec: PayloadSpec):
        """One client's upload path, pure JAX: pack ``out``, DP-clip the
        update against the shared reference when privacy is on, subtract
        the reference for delta codecs, add the client's error-feedback
        residual, encode/decode, and scatter the reconstructed payload
        into ``base`` (the server's tree). Returns (decoded tree, new
        residual, clip scale) — scale is 1.0 whenever nothing was clipped.
        """
        codec = self.codec
        flat = pack_stage_payload(out, spec)
        if self.privacy is not None and self.privacy.dp:
            flat, scale = self.privacy.clip_jax(flat, ref_flat)
        else:
            scale = jnp.float32(1.0)
        x = flat - ref_flat if codec.delta else flat
        if codec.error_feedback:
            x = x + res
        dec = codec.decode(codec.encode(x, spec), spec)
        new_res = x - dec if codec.error_feedback else res
        full = ref_flat + dec if codec.delta else dec
        return unpack_stage_payload(base, full, spec), new_res, scale

    def _upload_fn(self, spec: PayloadSpec):
        """(base, ref_flat, src, residual) -> (decoded tree, new residual,
        clip scale) for the sequential engine's per-client loop; the
        shared reference is packed once per round, not once per client.
        jit'd XLA in ``kernels="xla"`` mode, the fused kernel wire path in
        ``pallas``.
        """
        key = ("up", spec.sig)
        if key not in self._roundtrips:
            if self.kernels == "pallas":
                self._roundtrips[key] = self._kernel_upload_fn(spec)
            else:
                self._roundtrips[key] = jax.jit(
                    lambda base, ref_flat, src, res: self._upload_one(
                        src, base, ref_flat, res, spec))
        return self._roundtrips[key]

    def _pack_fn(self, spec: PayloadSpec):
        key = ("pack", spec.sig)
        if key not in self._roundtrips:
            if self.kernels == "pallas":
                self._roundtrips[key] = lambda tree: kernel_pack(tree, spec)
            else:
                self._roundtrips[key] = jax.jit(
                    lambda tree: pack_stage_payload(tree, spec))
        return self._roundtrips[key]

    # -- fused kernel wire path (kernels="pallas") --------------------------
    def _kernel_roundtrip(self, spec: PayloadSpec):
        """Host-callable encode+decode through the fused kernels for the
        non-delta codecs; see ``kernel_codec_fns`` for the split form."""
        enc, dec = kernel_codec_fns(self.codec, spec)
        return lambda flat: dec(enc(flat))

    def _kernel_upload_fn(self, spec: PayloadSpec):
        codec = self.codec
        privacy = self.privacy

        def clip(flat, ref_flat):
            # host-side mirror of the in-jit clip; pass-through (scale
            # 1.0) hands the pooled wire buffer back untouched
            if privacy is not None and privacy.dp:
                return privacy.clip_host(flat, ref_flat)
            return flat, np.float32(1.0)

        if codec.delta:
            assert isinstance(codec, TopKCodec), codec.name
            k = codec.k_for(spec)

            def fn(base, ref_flat, src, res):
                flat, scale = clip(kernel_pack(src, spec), ref_flat)
                idx, val, new_res = kops.wire_topk_encode_ef(
                    flat, ref_flat, res, k)
                full = _sparse_add(ref_flat, idx, val, spec.total)
                return kernel_unpack(base, full, spec), new_res, scale
        else:
            roundtrip = self._kernel_roundtrip(spec)

            def fn(base, ref_flat, src, res):
                flat, scale = clip(kernel_pack(src, spec), ref_flat)
                dec = roundtrip(flat)
                return kernel_unpack(base, dec, spec), res, scale
        return fn

    def make_wire_transform(self, spec: PayloadSpec):
        """Pure function for the vectorized engine: (client-stacked trees,
        unbatched server base tree, unbatched download-reference tree,
        (C, n) residuals) -> (decoded stacked trees, new residuals, (C,)
        clip scales). vmap-ed over clients inside the jit'd round — DP
        clipping included, so both engines clip with the same function."""
        def transform(stacked_outs, base, ref, residuals):
            ref_flat = pack_stage_payload(ref, spec)
            return jax.vmap(
                lambda out, res: self._upload_one(out, base, ref_flat, res,
                                                  spec)
            )(stacked_outs, residuals)

        return transform

    # -- error-feedback residuals -------------------------------------------
    def residual_shape(self, spec: PayloadSpec) -> Tuple[int, ...]:
        """(n,) when the codec carries error feedback, else a (1,) dummy."""
        return (spec.total,) if self.codec.error_feedback else (1,)

    def gather_residuals(self, client_ids, spec: PayloadSpec):
        """(C, n) residual rows for ``client_ids``; zeros for new clients or
        when the payload layout changed (stage transition resets EF)."""
        shape = self.residual_shape(spec)
        rows = []
        for cid in client_ids:
            held = self._resid.get(cid)
            if held is not None and held[0] == spec.sig:
                rows.append(held[1])
            else:
                rows.append(jnp.zeros(shape, WIRE_DTYPE))
        return jnp.stack(rows)

    def store_residuals(self, client_ids, spec: PayloadSpec, stacked):
        if not self.codec.error_feedback:
            return
        for i, cid in enumerate(client_ids):
            self._resid[cid] = (spec.sig, stacked[i])

    # -- driver-facing operations -------------------------------------------
    def _bcast_fn(self, spec: PayloadSpec):
        """Non-delta broadcast: (online) -> decoded client view (jit'd
        XLA, or the fused kernel wire path under ``kernels="pallas"``)."""
        key = ("down", spec.sig)
        if key not in self._roundtrips:
            codec = self.codec
            if self.kernels == "pallas":
                roundtrip = self._kernel_roundtrip(spec)

                def fn(online):
                    dec = roundtrip(kernel_pack(online, spec))
                    return kernel_unpack(online, dec, spec)
            else:
                @jax.jit
                def fn(online):
                    flat = pack_stage_payload(online, spec)
                    dec = codec.decode(codec.encode(flat, spec), spec)
                    return unpack_stage_payload(online, dec, spec)

            self._roundtrips[key] = fn
        return self._roundtrips[key]

    def _bcast_delta_fn(self, spec: PayloadSpec):
        """Delta broadcast: (online, mirror flat) -> (client view,
        new mirror). The mirror is the server's record of what clients
        already hold; sparsifying (model - mirror) and advancing the
        mirror by the *decoded* delta is error feedback in itself — what a
        round drops stays in the next round's delta."""
        key = ("down_delta", spec.sig)
        if key not in self._roundtrips:
            codec = self.codec
            if self.kernels == "pallas":
                assert isinstance(codec, TopKCodec), codec.name
                k = codec.k_for(spec)

                def fn(online, mirror):
                    flat = kernel_pack(online, spec)
                    idx, val, _ = kops.wire_topk_encode_ef(
                        flat, mirror, None, k)
                    new_mirror = _sparse_add(mirror, idx, val, spec.total)
                    return kernel_unpack(online, new_mirror,
                                         spec), new_mirror
            else:
                @jax.jit
                def fn(online, mirror):
                    flat = pack_stage_payload(online, spec)
                    dec = codec.decode(codec.encode(flat - mirror, spec),
                                       spec)
                    new_mirror = mirror + dec
                    return unpack_stage_payload(online, new_mirror,
                                                spec), new_mirror

            self._roundtrips[key] = fn
        return self._roundtrips[key]

    def broadcast(self, online, plan):
        """Server -> clients: route the download payload over the wire and
        return (the tree clients train from, measured download stats).

        Delta codecs (topk) need a shared reference: the first round under
        a payload layout (run start / stage transition) is a dense fp32
        re-sync that seeds the mirror; later rounds ship the sparsified
        difference against it."""
        spec = self.plan_specs(online, plan)["download"]
        with self.obs.tracer.span("wire.download", cat="transport",
                                  codec=self.codec.name,
                                  kernels=self.kernels) as sp:
            if not self.codec.delta:
                view = self._bcast_fn(spec)(online)
                wire = self.wire_bytes(spec)
            else:
                held = self._mirror
                if held is None or held[0] != spec.sig:
                    flat = self._pack_fn(spec)(online)
                    if self.kernels == "pallas":
                        view = kernel_unpack(online, flat, spec)
                    else:
                        view = unpack_stage_payload(online, flat, spec)
                    self._mirror = (spec.sig, flat)
                    wire = spec.payload_bytes      # dense sync round
                    sp.set(dense_sync=True)
                else:
                    view, mirror = self._bcast_delta_fn(spec)(online,
                                                              held[1])
                    self._mirror = (spec.sig, mirror)
                    wire = self.wire_bytes(spec)
            sp.set(wire_bytes=wire, payload_bytes=spec.payload_bytes)
        return view, {"wire_bytes": wire,
                      "payload_bytes": spec.payload_bytes}

    def decode_uploads(self, server_online, outs, client_ids, plan,
                       ref_online=None):
        """Clients -> server, without aggregation: per-client payload ->
        wire (-> EF residual) -> decoded tree. Returns (list of decoded
        trees, measured per-client upload stats). The buffered-async
        policy consumes this form — it holds individual updates across
        rounds and aggregates them staleness-weighted later."""
        spec = self.plan_specs(server_online, plan)["upload"]
        ref_online = server_online if ref_online is None else ref_online
        fn = self._upload_fn(spec)
        tracer = self.obs.tracer
        with tracer.span("wire.upload", cat="transport",
                         codec=self.codec.name, kernels=self.kernels,
                         clients=len(client_ids),
                         wire_bytes=self.wire_bytes(spec),
                         payload_bytes=spec.payload_bytes):
            ref_flat = self._pack_fn(spec)(ref_online)
            res = self.gather_residuals(client_ids, spec)
            trees, new_res, scales = [], [], []
            for cid, out, r in zip(client_ids, outs, res):
                # client ids are ints in the driver but any hashable in
                # direct Transport use — keep strings as-is in the span
                with tracer.span("wire.upload.client", cat="transport",
                                 client=cid if isinstance(cid, str)
                                 else int(cid),
                                 codec=self.codec.name):
                    tree, nr, sc = fn(server_online, ref_flat, out, r)
                trees.append(tree)
                new_res.append(nr)
                scales.append(sc)
            self.store_residuals(client_ids, spec, new_res)
        stats = dict(self.upload_stats(spec))
        stats["clip_fraction"] = float(
            np.mean(np.asarray(scales, np.float32) < 1.0))
        return trees, stats

    def aggregate_uploads(self, server_online, outs, client_ids, plan,
                          weights, ref_online=None):
        """Clients -> server, sequential form: per-client payload -> wire
        (-> EF residual) -> decoded tree; FedAvg over the decoded trees.
        ``ref_online`` is the downloaded tree clients started from — the
        shared reference delta codecs subtract. Returns (aggregated tree,
        measured per-client upload stats)."""
        trees, stats = self.decode_uploads(server_online, outs, client_ids,
                                           plan, ref_online=ref_online)
        return aggregate.fedavg(trees, weights), stats

    def upload_stats(self, spec: PayloadSpec) -> Dict[str, int]:
        return {"wire_bytes": self.wire_bytes(spec),
                "payload_bytes": spec.payload_bytes}
