from repro.federated.aggregate import fedavg, fedavg_stacked  # noqa: F401
from repro.federated.comm import round_comm_bytes, tree_bytes  # noqa: F401
from repro.federated.driver import run_fedssl  # noqa: F401
from repro.federated.engine import ENGINES, make_engine  # noqa: F401
from repro.federated.leaves import classify_leaf  # noqa: F401
from repro.federated.masks import stage_update_mask  # noqa: F401
from repro.federated.transport import (CODECS, Transport,  # noqa: F401
                                       make_codec, pack_stage_payload,
                                       unpack_stage_payload)
