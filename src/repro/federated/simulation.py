"""Round clock + pluggable round policies for the device-fleet simulator.

``repro.federated.fleet`` says *what hardware* each client has; this module
says *what time it costs* and *what the server does about it*. The round
clock prices one client's round as

  download_s   wire download bytes / device downlink bandwidth
  compute_s    max(FLOPs / device FLOP/s, HBM bytes / device mem-BW) —
               the two-term roofline, with FLOPs from the useful-work
               model in ``repro.roofline.analysis`` scaled to the round
               plan's sub-model and active suffix
  upload_s     wire upload bytes / device uplink bandwidth
  energy_j     FLOPs x J/FLOP + wire bytes x J/byte (device coefficients)

and a round policy turns per-client costs into scheduling decisions:

  synchronous     today's behavior — the server waits for every sampled
                  (available) client; round wall-clock is the slowest
                  participant.
  deadline        overcommit the sample (``overcommit`` x clients/round,
                  clamped to the population), drop clients that would
                  finish past the deadline, FedAvg the survivors. The
                  deadline is fixed (``deadline_s``) or adaptive (the
                  ``quantile`` of the cohort's predicted finish times).
                  Dropped-but-started clients still burn device-seconds
                  and energy up to the deadline.
  buffered-async  FedBuff-style: launched clients keep training across
                  round boundaries; the server aggregates as soon as
                  ``buffer`` updates have arrived, weighting each update
                  by its sample count times a polynomial staleness
                  discount ``(1 + staleness)^-alpha``, normalized.
                  Cross-stage stale updates are discarded at stage
                  transitions (the payload layout changes under them).

All scheduling state lives on the host in numpy (fleet draws, availability
draws, the clock), so decisions are identical across the sequential and
vmap engines and fully determined by the seed. The training computation
itself still runs through the engines/transport unchanged — with the
synchronous policy and a uniform fleet the driver's numerics are
bit-identical to running without a simulator. See docs/simulation.md.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.federated import aggregate
from repro.federated.fleet import Fleet, make_fleet
from repro.obs import NOOP_OBS
from repro.roofline import analysis

POLICIES = ("synchronous", "deadline", "buffered-async")


# ---------------------------------------------------------------------------
# round clock
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ClientRoundCost:
    download_s: float
    compute_s: float
    upload_s: float
    energy_j: float

    @property
    def total_s(self) -> float:
        return self.download_s + self.compute_s + self.upload_s


def plan_step_flops(model_cfg, plan, *, batch: int, tokens: int,
                    num_stages: int) -> float:
    """FLOPs one client spends on one local step under ``plan``.

    Priced with the roofline useful-work model: ``analysis.model_flops``
    gives 6·N·D (2 forward + 4 backward) for the stage-s sub-model; the
    layer-wise schedules run the full forward but backprop only through
    the active suffix, and representation alignment adds one extra
    forward through the global model.
    """
    layers = max(1, round(model_cfg.num_layers * plan.sub_layers
                          / max(1, num_stages)))
    sub_cfg = dataclasses.replace(model_cfg, num_layers=layers)
    shape = ShapeConfig("sim", seq_len=tokens, global_batch=batch,
                        kind="train")
    full = analysis.model_flops(sub_cfg, shape, "train")        # 6 N D
    bwd_frac = (plan.sub_layers - plan.active_from) / max(1, plan.sub_layers)
    mult = (2.0 + 4.0 * bwd_frac + (2.0 if plan.align else 0.0)) / 6.0
    return full * mult


def plan_step_bytes(model_cfg, plan, *, num_stages: int) -> float:
    """HBM-traffic proxy per local step: three fp32 passes over the
    sub-model's parameters (read params, read grads/opt state, write)."""
    layers = max(1, round(model_cfg.num_layers * plan.sub_layers
                          / max(1, num_stages)))
    sub_cfg = dataclasses.replace(model_cfg, num_layers=layers)
    return 3.0 * 4.0 * sub_cfg.param_count()


def price_client_round(dev, *, steps: int, step_flops: float,
                       step_bytes: float, down_bytes: int,
                       up_bytes: int) -> ClientRoundCost:
    """Two-term roofline compute time + link-bound comm time + energy."""
    flops = steps * step_flops
    compute_s = max(flops / dev.flops, steps * step_bytes / dev.mem_bw)
    down_s = down_bytes / dev.down_bw
    up_s = up_bytes / dev.up_bw
    energy = flops * dev.j_per_flop + (down_bytes + up_bytes) * dev.j_per_byte
    return ClientRoundCost(down_s, compute_s, up_s, energy)


def staleness_weights(sample_counts: Sequence[int],
                      staleness: Sequence[int],
                      alpha: float = 0.5) -> np.ndarray:
    """FedBuff-style aggregation weights: sample count x polynomial
    staleness discount ``(1 + s)^-alpha``, normalized to sum to 1.
    Monotonically non-increasing in staleness at fixed sample count."""
    w = (np.asarray(sample_counts, np.float64)
         * (1.0 + np.asarray(staleness, np.float64)) ** (-alpha))
    return w / w.sum()


# ---------------------------------------------------------------------------
# round outcome record
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RoundOutcome:
    """Everything a policy decided for one round (host-side, deterministic
    given the seed — the determinism tests compare these across engines)."""
    round_idx: int
    cohort: Tuple[int, ...]        # sampled (possibly overcommitted) ids
    train_ids: Tuple[int, ...]     # clients that run local training now
    aggregated: Tuple[int, ...]    # ids whose updates enter aggregation
    staleness: Tuple[int, ...]     # per aggregated id, in rounds
    weights: Optional[Tuple[float, ...]]  # None => engine-standard FedAvg
    dropped: Tuple[int, ...]       # launched/sampled but not aggregated
    wall_clock_s: float
    device_seconds: float
    energy_j: float
    deadline_s: Optional[float]


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------
class SynchronousPolicy:
    """Today's behavior: every sampled available client trains and is
    aggregated; the server waits for the slowest one."""

    name = "synchronous"
    overcommit = 1.0
    needs_client_trees = False

    def begin_stage(self):
        pass

    def resolve(self, round_idx, cohort, costs, available):
        alive = [c for c in cohort if available[c]]
        if not alive:   # server re-polls until someone answers
            alive = [min(cohort, key=lambda c: costs[c].total_s)]
        times = [costs[c].total_s for c in alive]
        return RoundOutcome(
            round_idx=round_idx, cohort=tuple(cohort),
            train_ids=tuple(alive), aggregated=tuple(alive),
            staleness=(0,) * len(alive), weights=None,
            dropped=tuple(c for c in cohort if c not in alive),
            wall_clock_s=max(times),
            device_seconds=sum(times),
            energy_j=sum(costs[c].energy_j for c in alive),
            deadline_s=None)


class DeadlinePolicy:
    """Overcommit the sample, drop predicted stragglers past the deadline,
    FedAvg the survivors with plain (sample-count) weights."""

    name = "deadline"
    needs_client_trees = False

    def __init__(self, deadline_s: Optional[float] = None,
                 overcommit: float = 1.5, quantile: float = 0.6):
        if overcommit < 1.0:
            raise ValueError(f"overcommit must be >= 1: {overcommit}")
        if not (0.0 < quantile <= 1.0):
            raise ValueError(f"quantile must be in (0, 1]: {quantile}")
        self.deadline_s = deadline_s
        self.overcommit = float(overcommit)
        self.quantile = float(quantile)

    def begin_stage(self):
        pass

    def resolve(self, round_idx, cohort, costs, available):
        alive = [c for c in cohort if available[c]]
        if not alive:
            alive = [min(cohort, key=lambda c: costs[c].total_s)]
        times = {c: costs[c].total_s for c in alive}
        deadline = (self.deadline_s if self.deadline_s is not None
                    else float(np.quantile(list(times.values()),
                                           self.quantile)))
        survivors = [c for c in alive if times[c] <= deadline]
        if not survivors:
            survivors = [min(alive, key=times.get)]
        cut = [c for c in alive if c not in survivors]
        # survivors run to completion; cut clients burn device time and
        # energy until the deadline, then the server stops waiting
        dev_s = sum(times[c] for c in survivors) + sum(
            min(times[c], deadline) for c in cut)
        energy = sum(costs[c].energy_j for c in survivors) + sum(
            costs[c].energy_j * min(1.0, deadline / max(times[c], 1e-12))
            for c in cut)
        wall = deadline if cut else max(times[c] for c in survivors)
        return RoundOutcome(
            round_idx=round_idx, cohort=tuple(cohort),
            train_ids=tuple(survivors), aggregated=tuple(survivors),
            staleness=(0,) * len(survivors), weights=None,
            dropped=tuple(c for c in cohort if c not in survivors),
            wall_clock_s=wall, device_seconds=dev_s, energy_j=energy,
            deadline_s=deadline)


@dataclass
class _Pending:
    client_id: int
    origin_round: int
    arrival_s: float          # absolute simulated time of arrival
    samples: int
    cost: ClientRoundCost
    tree: object = None       # decoded update, attached after training


class BufferedAsyncPolicy:
    """FedBuff-style buffered asynchronous aggregation.

    Clients launched at round t keep running across round boundaries; the
    server aggregates whenever ``buffer`` updates have arrived, weighting
    each by sample count x ``(1 + staleness)^-alpha`` (normalized). Needs
    per-client update trees from the engine (``needs_client_trees``),
    because stale updates are held and averaged rounds after they were
    computed.
    """

    name = "buffered-async"
    overcommit = 1.0
    needs_client_trees = True

    def __init__(self, buffer: int = 0, alpha: float = 0.5):
        if alpha < 0.0:
            raise ValueError(f"staleness alpha must be >= 0: {alpha}")
        self.buffer = int(buffer)     # 0 => half the cohort, at least 1
        self.alpha = float(alpha)
        self._pending: List[_Pending] = []
        self._clock = 0.0
        self._flushed: List[int] = []

    def begin_stage(self):
        # stale updates have the previous stage's payload semantics —
        # discard them (counted as drops in the next round's outcome)
        self._flushed.extend(p.client_id for p in self._pending)
        self._pending = []

    def _buffer_size(self, cohort_size: int) -> int:
        return self.buffer if self.buffer > 0 else max(1, cohort_size // 2)

    def resolve(self, round_idx, cohort, costs, available):
        busy = {p.client_id for p in self._pending}
        candidates = [c for c in cohort if c not in busy]
        alive = [c for c in candidates if available[c]]
        n_new = max(0, len(cohort) - len(self._pending))
        launch = alive[:n_new]
        if not launch and not self._pending:
            launch = [min(cohort, key=lambda c: costs[c].total_s)]
        unavailable = [c for c in candidates[:n_new] if c not in alive]
        dropped = tuple(unavailable) + tuple(self._flushed)
        self._flushed = []
        # aggregation set / clock / weights are finalized in ``complete``;
        # device time and energy are accounted at launch
        return RoundOutcome(
            round_idx=round_idx, cohort=tuple(cohort),
            train_ids=tuple(launch), aggregated=(), staleness=(),
            weights=None, dropped=dropped,
            wall_clock_s=0.0,
            device_seconds=sum(costs[c].total_s for c in launch),
            energy_j=sum(costs[c].energy_j for c in launch),
            deadline_s=None)

    def complete(self, outcome: RoundOutcome, costs, counts, trees,
                 agg_fn=None):
        """Attach the newly trained update trees, pop the ``buffer``
        earliest arrivals, and return (aggregated model, final outcome).

        ``agg_fn(trees, weights, client_ids)`` replaces the plain FedAvg —
        secure aggregation masks over each flush's arrival set (survivor-
        set re-masking, see docs/privacy.md)."""
        for cid, tree in zip(outcome.train_ids, trees):
            self._pending.append(_Pending(
                cid, outcome.round_idx,
                self._clock + costs[cid].total_s, counts[cid],
                costs[cid], tree))
        self._pending.sort(key=lambda p: (p.arrival_s, p.client_id))
        k = min(self._buffer_size(len(outcome.cohort)), len(self._pending))
        arrived, self._pending = self._pending[:k], self._pending[k:]
        t0 = self._clock
        self._clock = max(self._clock, arrived[-1].arrival_s)
        stale = [outcome.round_idx - p.origin_round for p in arrived]
        w = staleness_weights([p.samples for p in arrived], stale,
                              self.alpha)
        if agg_fn is not None:
            new_online = agg_fn([p.tree for p in arrived],
                                tuple(float(x) for x in w),
                                tuple(p.client_id for p in arrived))
        else:
            new_online = aggregate.fedavg(
                [p.tree for p in arrived],
                jnp.asarray(w, jnp.float32))
        final = dataclasses.replace(
            outcome,
            aggregated=tuple(p.client_id for p in arrived),
            staleness=tuple(stale),
            weights=tuple(float(x) for x in w),
            wall_clock_s=self._clock - t0)
        return new_online, final


def make_policy(name: str, **kw):
    """Policy registry. kwargs: deadline => deadline_s / overcommit /
    quantile; buffered-async => buffer / alpha."""
    if name == "synchronous":
        if kw:
            raise ValueError(f"synchronous policy takes no options: {kw}")
        return SynchronousPolicy()
    if name == "deadline":
        return DeadlinePolicy(**kw)
    if name == "buffered-async":
        return BufferedAsyncPolicy(**kw)
    raise ValueError(f"unknown round policy '{name}'; one of {POLICIES}")


# ---------------------------------------------------------------------------
# simulation orchestrator (the driver's single point of contact)
# ---------------------------------------------------------------------------
class Simulation:
    """Binds a fleet to a round policy and owns the host-side randomness
    (availability draws) and the per-round outcome log."""

    def __init__(self, fleet: Fleet, policy, *, seed: int = 0, obs=None):
        self.fleet = fleet
        self.policy = policy
        # availability stream is independent of the jax training chain:
        # the simulator never consumes main-loop PRNG keys
        self._avail_rng = np.random.default_rng([seed, 0x5EED])
        self.records: List[RoundOutcome] = []
        self._prepared = False
        # observability: policy decisions become instant events; each
        # trained client's simulated round becomes a span on its own
        # virtual track, laid out on the cumulative simulated clock —
        # a fleet round reads like a real profile in Perfetto
        self.obs = obs if obs is not None else NOOP_OBS
        self._vclock = 0.0

    @property
    def overcommit(self) -> float:
        return self.policy.overcommit

    def prepare(self, model_cfg, *, num_stages: int, counts: Sequence[int],
                batch: int, tokens: int, local_epochs: int):
        """Called once per run with the workload's pricing inputs."""
        if len(counts) != len(self.fleet):
            raise ValueError(
                f"fleet has {len(self.fleet)} devices but the run has "
                f"{len(counts)} clients — build the fleet with "
                f"make_fleet(profile, num_clients, seed)")
        self.model_cfg = model_cfg
        self.num_stages = num_stages
        self.counts = list(counts)
        self.batch = batch
        self.tokens = tokens
        self.steps = [local_epochs * (n // batch) for n in counts]
        self._prepared = True

    def begin_stage(self):
        self.policy.begin_stage()

    def round_costs(self, plan, cohort, *, down_bytes: int,
                    up_bytes: int) -> Dict[int, ClientRoundCost]:
        step_f = plan_step_flops(self.model_cfg, plan, batch=self.batch,
                                 tokens=self.tokens,
                                 num_stages=self.num_stages)
        step_b = plan_step_bytes(self.model_cfg, plan,
                                 num_stages=self.num_stages)
        return {c: price_client_round(
            self.fleet[c], steps=self.steps[c], step_flops=step_f,
            step_bytes=step_b, down_bytes=down_bytes, up_bytes=up_bytes)
            for c in cohort}

    def begin_round(self, plan, cohort, *, down_bytes: int,
                    up_bytes: int) -> RoundOutcome:
        """Price the cohort, draw availability, let the policy schedule.
        Returns the (possibly provisional, for async) round outcome; the
        driver trains ``outcome.train_ids``."""
        assert self._prepared, "call prepare() before begin_round()"
        self._costs = self.round_costs(plan, cohort, down_bytes=down_bytes,
                                       up_bytes=up_bytes)
        draws = self._avail_rng.random(len(cohort))
        available = {c: bool(draws[i] < self.fleet[c].availability)
                     for i, c in enumerate(cohort)}
        outcome = self.policy.resolve(len(self.records), cohort,
                                      self._costs, available)
        self.obs.tracer.instant(
            f"policy.{self.policy.name}", cat="sim",
            round=outcome.round_idx, cohort=list(outcome.cohort),
            train=list(outcome.train_ids), dropped=list(outcome.dropped),
            deadline_s=outcome.deadline_s)
        return outcome

    def _emit_round_spans(self, outcome: RoundOutcome):
        """Per-client simulated-round spans on the virtual timeline (one
        track per client, timestamps in cumulative simulated seconds)."""
        tracer = self.obs.tracer
        for cid in outcome.train_ids:
            cost = self._costs[cid]
            dur = cost.total_s
            if outcome.deadline_s is not None:
                dur = min(dur, outcome.deadline_s)
            tracer.virtual_span(
                f"client {cid} round {outcome.round_idx}",
                f"sim client {cid}", self._vclock, dur,
                client=cid, round=outcome.round_idx,
                download_s=cost.download_s, compute_s=cost.compute_s,
                upload_s=cost.upload_s, energy_j=cost.energy_j)
        self._vclock += outcome.wall_clock_s

    def complete_round(self, outcome: RoundOutcome) -> RoundOutcome:
        """Synchronous/deadline: the provisional outcome is final."""
        self.records.append(outcome)
        self._emit_round_spans(outcome)
        return outcome

    def complete_round_async(self, outcome: RoundOutcome, trees,
                             agg_fn=None) -> Tuple[object, RoundOutcome]:
        """Buffered-async: hand the per-client decoded trees to the
        policy's buffer; returns (aggregated online tree, final outcome).
        ``agg_fn`` (optional) replaces the buffer's FedAvg — the secure-
        aggregation hook."""
        new_online, final = self.policy.complete(outcome, self._costs,
                                                 self.counts, trees,
                                                 agg_fn=agg_fn)
        self.records.append(final)
        self._emit_round_spans(final)
        return new_online, final


def make_sim(fleet, policy="synchronous", *, num_clients: int,
             seed: int = 0, **policy_kw) -> Simulation:
    """Convenience constructor: fleet/policy by name or instance.

    ``make_sim("pareto-stragglers", "deadline", num_clients=32, seed=0,
    overcommit=1.5)``
    """
    if isinstance(fleet, str):
        fleet = make_fleet(fleet, num_clients, seed)
    if isinstance(policy, str):
        policy = make_policy(policy, **policy_kw)
    elif policy_kw:
        raise ValueError("policy_kw only applies when policy is a name")
    return Simulation(fleet, policy, seed=seed)
