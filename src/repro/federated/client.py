"""Client-side local SSL training (paper Algorithm 2).

``make_local_step`` builds the jit'd per-batch train step for a given
(stage, schedule) configuration; ``local_train`` runs E local epochs.
The online branch, target branch and optimizer state are all local to the
client for the duration of the round; the target branch is re-initialized
from the downloaded global model at round start (Algorithm 2, lines 2-3).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schedule as sched
from repro.core import ssl as ssl_mod
from repro.data.augment import two_views
from repro.federated.masks import stage_update_mask


def make_local_step(encoder, ssl_cfg, opt, *, sub_layers: int,
                    active_from: int, align: bool, depth_dropout: float):
    """Returns jit'd step(state, opt_state, images, key, lr, global_enc)."""
    align_w = ssl_cfg.align_weight if align else 0.0

    @jax.jit
    def step(state, opt_state, images, key, lr, global_enc):
        k_aug, k_dd = jax.random.split(key)
        x1, x2 = two_views(k_aug, images)
        gates = None
        if depth_dropout > 0.0:
            gates = sched.depth_dropout_gates(
                k_dd, encoder.num_stages, active_from, depth_dropout)

        def loss_fn(online):
            st = {**state, "online": online}
            return ssl_mod.ssl_loss(
                st, x1, x2, encoder, ssl_cfg, sub_layers=sub_layers,
                active_from=active_from, layer_gates=gates,
                global_enc=global_enc, align_weight=align_w)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["online"])
        mask = stage_update_mask(state["online"], sub_layers, active_from)
        new_online, opt_state = opt.update(grads, opt_state,
                                           state["online"], lr, mask)
        state = {**state, "online": new_online}
        state = ssl_mod.momentum_update(state, ssl_cfg.momentum)
        return state, opt_state, metrics

    return step


def local_train(global_state, images, step_fn, opt, *, epochs: int,
                batch_size: int, key, lr, global_enc=None):
    """Run E local epochs (Algorithm 2). Returns (online_params, metrics).

    ``images``: (n_i, H, W, 3) this client's local shard.
    """
    state = {
        "online": jax.tree.map(jnp.asarray, global_state["online"]),
    }
    if "target" in global_state:
        # target branch re-initialized from the global model each round
        state["target"] = {
            "enc": jax.tree.map(jnp.copy, global_state["online"]["enc"]),
            "proj": jax.tree.map(jnp.copy, global_state["online"]["proj"]),
        }
    opt_state = opt.init(state["online"])
    n = images.shape[0]
    steps = 0
    last = {}
    for e in range(epochs):
        key, kp = jax.random.split(key)
        perm = jax.random.permutation(kp, n)
        nb = n // batch_size
        for b in range(nb):
            key, kb = jax.random.split(key)
            sel = jax.lax.dynamic_slice_in_dim(perm, b * batch_size,
                                               batch_size)
            batch = images[sel]
            state, opt_state, last = step_fn(state, opt_state, batch, kb,
                                             lr, global_enc)
            steps += 1
    return state["online"], {**last, "steps": steps}


def replay_batch_plan(key, n: int, epochs: int, batch_size: int,
                      total_steps: int):
    """Host-side replay of ``local_train``'s RNG/batch chain for one client.

    Performs exactly the key splits and permutations ``local_train`` would,
    so the vectorized engine (``repro.federated.engine``) consumes identical
    batches and per-step keys and matches the sequential reference. Returns

        batch_idx  (total_steps, batch_size) int32 — shard-local positions
        step_keys  (total_steps, 2) uint32         — per-step PRNG keys
        valid      (total_steps,) bool             — False for padded steps

    Clients with fewer than ``total_steps`` real steps (ragged shards) are
    padded at the end; padded steps carry index 0 / key 0 and must be
    masked out by the caller.
    """
    nb = n // batch_size
    if epochs * nb > total_steps:
        raise ValueError(f"client needs {epochs * nb} steps > padded "
                         f"budget {total_steps}")
    batch_idx, step_keys = _replay_plan_jit(
        key, n=n, epochs=epochs, batch_size=batch_size,
        total_steps=total_steps)
    valid = np.zeros((total_steps,), bool)
    valid[:epochs * nb] = True
    return batch_idx, step_keys, valid


@functools.partial(jax.jit,
                   static_argnames=("n", "epochs", "batch_size",
                                    "total_steps"))
def _replay_plan_jit(key, *, n, epochs, batch_size, total_steps):
    """The split/permute chain of ``local_train``, unrolled in one program
    so the vmap engine pays one dispatch per client instead of one per
    split."""
    nb = n // batch_size
    batch_idx = jnp.zeros((total_steps, batch_size), jnp.int32)
    step_keys = jnp.zeros((total_steps, 2), jnp.uint32)
    t = 0
    for _ in range(epochs):
        key, kp = jax.random.split(key)
        perm = jax.random.permutation(kp, n).astype(jnp.int32)
        for b in range(nb):
            key, kb = jax.random.split(key)
            batch_idx = batch_idx.at[t].set(
                perm[b * batch_size:(b + 1) * batch_size])
            step_keys = step_keys.at[t].set(kb)
            t += 1
    return batch_idx, step_keys
