"""Shared leaf classification for the federated stack.

Every piece of FL machinery that walks a parameter pytree — update masks
(``masks.py``), analytic communication accounting (``comm.py``) and the
wire-level transport (``transport.py``) — must agree on what each leaf *is*:

  stacked   a per-stage block stack (leading dim = stage axis); the round
            plan's ``[lo, hi)`` stage range selects rows of it.
  embed     input-side parameters (token/patch embeddings, positional
            embeddings, CLS token, LM head): trainable / exchanged only
            when the stage prefix is active (``active_from == 0``).
  head      SSL projection & prediction MLPs: always trained locally;
            exchanged by default. ``include_heads=False`` drops them from
            both comm accounting and the wire (encoder-only exchange);
            note the single-copy simulator then discards local head
            training each round rather than persisting per-client heads.
  extra     everything else that travels with the encoder whenever any
            stage moves (final norm, Zamba's shared attention block, conv
            stubs): always trained, always exchanged.

``classify_leaf`` is the single source of truth for that mapping; the three
consumers only differ in what they *do* with the answer (mask, count bytes,
or slice onto the wire).
"""
from __future__ import annotations

from typing import Tuple

STACKED_KEYS = ("blocks", "moe_blocks", "mlstm", "slstm", "enc_blocks",
                "dec_blocks")
EMBED_KEYS = ("embed", "patch", "pos", "cls", "lm_head")
HEAD_KEYS = ("proj", "pred")

KINDS = ("stacked", "embed", "head", "extra")


def path_keys(path) -> Tuple[str, ...]:
    """Key-path entries of a ``tree_flatten_with_path`` path, as strings."""
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def classify_leaf(path) -> str:
    """Map a leaf's key path to one of ``KINDS``."""
    keys = path_keys(path)
    if any(k in STACKED_KEYS for k in keys):
        return "stacked"
    if any(k in EMBED_KEYS for k in keys):
        return "embed"
    if any(k in HEAD_KEYS for k in keys):
        return "head"
    return "extra"
