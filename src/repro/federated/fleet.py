"""Heterogeneous device-fleet model for the FL simulator.

A ``Fleet`` assigns every client a ``DeviceProfile`` — sustained compute
throughput, memory bandwidth, up/down link bandwidth, per-round
availability and energy coefficients — drawn from a *named, seeded*
profile distribution. Draws use numpy's PCG64 generator seeded from
``(seed, profile id)``, so a fleet is a pure function of
``(profile, num_clients, seed)``: identical across runs, engines and
platforms, and different seeds give different fleets.

Profiles (``make_fleet``):

  uniform             every client is exactly the reference edge device
                      (availability 1.0). The simulator's "no heterogeneity"
                      baseline — under the synchronous policy this is
                      provably identical to running without a simulator.
  mobile-mix          a hi/mid/lo device-tier mixture (20/50/30%) with
                      log-normal per-device jitter and tiered link
                      bandwidth/availability — the "fleet of phones"
                      picture in Alawadi et al.
  pareto-stragglers   compute slowdowns drawn from a Pareto tail: most
                      clients are near-reference, a heavy tail is many
                      times slower. The classic straggler regime that
                      deadline/async policies exist for.

The reference-device constants are first-order edge numbers (a mobile
NPU/GPU class device on a fast WAN link); they set the *scale* of
simulated seconds and joules, while scheduling decisions only depend on
the ratios between clients.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

# reference edge device (a mid-range phone SoC on WiFi/LTE)
REF_FLOPS = 200e9        # sustained FLOP/s
REF_MEM_BW = 20e9        # bytes/s
REF_DOWN_BW = 12.5e6     # bytes/s  (~100 Mbit/s down)
REF_UP_BW = 5e6          # bytes/s  (~40 Mbit/s up)
REF_J_PER_FLOP = 1e-11   # 10 pJ/FLOP compute energy proxy
REF_J_PER_BYTE = 1e-7    # 100 nJ/byte radio energy proxy

PROFILES = ("uniform", "mobile-mix", "pareto-stragglers")


@dataclass(frozen=True)
class DeviceProfile:
    """One client's simulated hardware."""
    flops: float           # sustained compute throughput, FLOP/s
    mem_bw: float          # memory bandwidth, bytes/s
    down_bw: float         # downlink, bytes/s
    up_bw: float           # uplink, bytes/s
    availability: float    # P(client is reachable for a round it's sampled)
    j_per_flop: float      # energy proxy, joules per FLOP
    j_per_byte: float      # energy proxy, joules per wire byte


REFERENCE_DEVICE = DeviceProfile(
    flops=REF_FLOPS, mem_bw=REF_MEM_BW, down_bw=REF_DOWN_BW,
    up_bw=REF_UP_BW, availability=1.0, j_per_flop=REF_J_PER_FLOP,
    j_per_byte=REF_J_PER_BYTE)


@dataclass(frozen=True)
class Fleet:
    profile: str
    seed: int
    devices: Tuple[DeviceProfile, ...]

    def __len__(self):
        return len(self.devices)

    def __getitem__(self, i) -> DeviceProfile:
        return self.devices[i]

    @property
    def homogeneous(self) -> bool:
        return all(d == self.devices[0] for d in self.devices)

    def draw_signature(self) -> Tuple:
        """Hashable fingerprint of every drawn number — what the
        determinism property tests compare across runs and engines."""
        return tuple((d.flops, d.mem_bw, d.down_bw, d.up_bw,
                      d.availability) for d in self.devices)


def _rng(profile: str, num_clients: int, seed: int) -> np.random.Generator:
    # seed sequence keyed on every argument: same args => same fleet,
    # different seed/profile/size => statistically independent draws
    return np.random.default_rng(
        [seed, num_clients, PROFILES.index(profile)])


def _uniform(num_clients: int, rng) -> Tuple[DeviceProfile, ...]:
    return (REFERENCE_DEVICE,) * num_clients


def _mobile_mix(num_clients: int, rng) -> Tuple[DeviceProfile, ...]:
    # (speed multiplier, link multiplier, availability) per tier
    tiers = np.asarray([[2.0, 2.0, 0.95],    # hi: flagship on WiFi
                        [1.0, 1.0, 0.90],    # mid: the reference device
                        [0.35, 0.5, 0.75]])  # lo: old phone, flaky uplink
    pick = rng.choice(3, size=num_clients, p=[0.2, 0.5, 0.3])
    jitter = rng.lognormal(mean=0.0, sigma=0.2, size=num_clients)
    devs = []
    for i in range(num_clients):
        speed, link, avail = tiers[pick[i]]
        s = float(speed * jitter[i])
        devs.append(DeviceProfile(
            flops=REF_FLOPS * s, mem_bw=REF_MEM_BW * s,
            down_bw=REF_DOWN_BW * float(link),
            up_bw=REF_UP_BW * float(link),
            availability=float(avail),
            # slower silicon is also less efficient per op
            j_per_flop=REF_J_PER_FLOP / min(1.0, s) ** 0.5,
            j_per_byte=REF_J_PER_BYTE))
    return tuple(devs)


def _pareto_stragglers(num_clients: int, rng) -> Tuple[DeviceProfile, ...]:
    # slowdown = 1 + Pareto(a=1.5): mode at reference speed, heavy tail of
    # clients that are many times slower (infinite-variance regime)
    slowdown = 1.0 + rng.pareto(1.5, size=num_clients)
    devs = []
    for i in range(num_clients):
        s = float(slowdown[i])
        devs.append(DeviceProfile(
            flops=REF_FLOPS / s, mem_bw=REF_MEM_BW / s,
            down_bw=REF_DOWN_BW, up_bw=REF_UP_BW,
            availability=0.9,
            j_per_flop=REF_J_PER_FLOP * s ** 0.5,
            j_per_byte=REF_J_PER_BYTE))
    return tuple(devs)


_MAKERS = {"uniform": _uniform, "mobile-mix": _mobile_mix,
           "pareto-stragglers": _pareto_stragglers}


def make_fleet(profile: str, num_clients: int, seed: int = 0) -> Fleet:
    """Draw a fleet of ``num_clients`` devices from a named profile.

    Pure in all arguments — same (profile, num_clients, seed) always
    yields the identical fleet.
    """
    if profile not in _MAKERS:
        raise ValueError(f"unknown fleet profile '{profile}'; "
                         f"one of {PROFILES}")
    rng = _rng(profile, num_clients, seed)
    return Fleet(profile, seed, _MAKERS[profile](num_clients, rng))
