"""Communication-cost accounting (paper Figs. 5c/5d, Tables 1-3).

Bytes are derived from the actual parameter pytrees: a stage range selects
the slice of every stacked block leaf; embedding-side and head parameters
are added according to the flags. Downloads/uploads per round follow the
``RoundPlan`` produced by ``repro.core.schedule``.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.federated.masks import EMBED_KEYS, STACKED_KEYS, _path_keys


def tree_bytes(tree) -> int:
    return int(sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(tree)))


def _leaf_bytes(path, a, stage_range, include_embed, include_heads):
    keys = _path_keys(path)
    stacked = next((k for k in keys if k in STACKED_KEYS), None)
    itemsize = a.dtype.itemsize
    if stacked is not None:
        lo, hi = stage_range
        lo, hi = max(0, lo), min(a.shape[0], hi)
        per = int(np.prod(a.shape[1:])) * itemsize
        return max(0, hi - lo) * per
    if any(k in EMBED_KEYS for k in keys):
        return int(np.prod(a.shape)) * itemsize if include_embed else 0
    is_head = any(k in ("proj", "pred") for k in keys)
    if is_head:
        return int(np.prod(a.shape)) * itemsize if include_heads else 0
    # final_ln / shared_attn / misc encoder-side leaves travel with the
    # encoder whenever any stage moves.
    return int(np.prod(a.shape)) * itemsize if include_embed else 0


def partial_bytes(params, stage_range, *, include_embed=True,
                  include_heads=True) -> int:
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        total += _leaf_bytes(path, leaf, stage_range, include_embed,
                             include_heads)
    return total


def round_comm_bytes(params, plan, *, include_heads=True) -> dict:
    """Bytes for one client in one round under ``plan`` (a RoundPlan)."""
    down = partial_bytes(params, plan.download_stages,
                         include_embed=(plan.download_stages[0] == 0),
                         include_heads=include_heads)
    up = partial_bytes(params, plan.upload_stages,
                       include_embed=(plan.upload_stages[0] == 0
                                      and plan.sub_layers == plan.stage),
                       include_heads=include_heads)
    return {"download": down, "upload": up}
