"""Communication-cost accounting (paper Figs. 5c/5d, Tables 1-3).

Bytes are derived from the actual parameter pytrees: a stage range selects
the slice of every stacked block leaf; embedding-side and head parameters
are added according to the flags. Downloads/uploads per round follow the
``RoundPlan`` produced by ``repro.core.schedule``.

These numbers are the *analytic* prediction. ``repro.federated.transport``
materializes the same payloads on a real wire path; ``plan_payloads`` below
is the shared membership rule, so with the identity codec the transport's
measured bytes equal ``round_comm_bytes`` exactly.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.federated.leaves import classify_leaf


def tree_bytes(tree) -> int:
    return int(sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(tree)))


def _leaf_bytes(path, a, stage_range, include_embed, include_heads):
    kind = classify_leaf(path)
    itemsize = a.dtype.itemsize
    full = int(np.prod(a.shape)) * itemsize
    if kind == "stacked":
        lo, hi = stage_range
        lo, hi = max(0, lo), min(a.shape[0], hi)
        per = int(np.prod(a.shape[1:])) * itemsize
        return max(0, hi - lo) * per
    if kind == "embed":
        return full if include_embed else 0
    if kind == "head":
        return full if include_heads else 0
    # extra leaves (final_ln / shared_attn / conv stubs) travel with the
    # encoder whenever any stage moves — they are trained every round
    # (see masks.stage_update_mask), so both directions always carry them.
    return full


def partial_bytes(params, stage_range, *, include_embed=True,
                  include_heads=True) -> int:
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        total += _leaf_bytes(path, leaf, stage_range, include_embed,
                             include_heads)
    return total


def plan_payloads(plan) -> dict:
    """Per-direction payload membership for a ``RoundPlan``: maps
    ``download``/``upload`` to ``(stage_range, include_embed)``.

    Download carries the embedding side only when the range starts at the
    input (``lo == 0``): otherwise the client's cached prefix is current.
    Upload carries it only when the client actually trained it
    (``active_from == 0`` — the condition ``stage_update_mask`` uses), not
    the historical ``sub_layers == stage`` check, which was vacuously true
    for every staged schedule. Shared with the transport so analytic and
    measured bytes count the same tensors.
    """
    return {
        "download": (plan.download_stages, plan.download_stages[0] == 0),
        "upload": (plan.upload_stages, plan.active_from == 0),
    }


def round_comm_bytes(params, plan, *, include_heads=True) -> dict:
    """Bytes for one client in one round under ``plan`` (a RoundPlan)."""
    payloads = plan_payloads(plan)
    return {d: partial_bytes(params, rng, include_embed=emb,
                             include_heads=include_heads)
            for d, (rng, emb) in payloads.items()}
