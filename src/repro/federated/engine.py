"""Multi-client round execution engines for the FL driver.

Two interchangeable engines run the "train the sampled clients, then
aggregate" middle of a communication round (``repro.federated.driver`` owns
the stage schedule, LR, server calibration and comm accounting around them):

  sequential  the numerical reference — a Python loop over participants,
              each running ``client.local_train`` batch by batch.
  vmap        the vectorized engine — clients' shards are stacked on a
              leading axis (``data.partition.stack_shards``), the per-batch
              local step is ``jax.vmap``-ed over that axis and driven by a
              single ``lax.scan`` over local steps, and FedAvg
              (``aggregate.fedavg_stacked``) is fused into the same jit'd
              program: one XLA dispatch executes the whole round.

Parity: the vmap engine replays the sequential driver's exact per-client
RNG chain on the host (``client.replay_batch_plan``) and feeds the
resulting batch indices / per-step keys into the compiled program, so both
engines consume identical data in identical order; ragged shards are
padded to the longest client and padded steps are masked to a no-op.
See docs/engine.md.

Uploads route through the wire transport (``repro.federated.transport``)
in both engines: each client's result is packed into the round plan's
stage payload, encoded/decoded by the configured codec, and FedAvg
consumes the *decoded* trees reassembled onto the server's model. In the
vmap engine that whole path — pack, codec, error-feedback residual
update, FedAvg — is vmapped over clients inside the same jit'd round
program. With the identity (fp32) codec the round is bit-identical to
pre-transport behavior. See docs/transport.md.

The transport's host-called wire path (broadcast / upload decode) itself
has two engines, selected by ``Transport(kernels=...)`` /
``--transport-kernels``: the jit'd XLA reference and the fused Pallas
pack/codec kernels (docs/kernels.md). Both round engines pick that up
transparently — the sequential engine through ``aggregate_uploads``, the
vmap engine for its broadcasts; the vmap engine's *in-program* upload
path (``make_wire_transform``) stays XLA by design, since it is traced
into the jit'd round program.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.partition import stack_shards
from repro.federated import aggregate, client as client_mod
from repro.federated import transport as transport_mod
from repro.obs import NOOP_OBS

ENGINES = ("sequential", "vmap")


def _pool_len(pool) -> int:
    return jax.tree.leaves(pool)[0].shape[0]


def _abstract_round_inputs(encoder, ssl_cfg, opt, images, batch_size):
    """Shape-only (eval_shape) state/opt/batch trees for AOT lowering —
    no parameters are materialized."""
    from repro.core import ssl as ssl_mod
    state = jax.eval_shape(
        lambda k: ssl_mod.ssl_init(k, encoder, ssl_cfg),
        jax.random.PRNGKey(0))
    opt_state = jax.eval_shape(opt.init, state["online"])
    img = jax.ShapeDtypeStruct((batch_size,) + tuple(images.shape[1:]),
                               images.dtype)
    return state, opt_state, img


def jit_cache_entries(fns) -> int:
    """Total compiled-specialization count across ``fns`` — jit'd
    callables expose ``_cache_size()``; plain host functions (the pallas
    wire path) count zero. The driver's jit-recompile counter diffs this
    against the previous round to surface silent retraces."""
    total = 0
    for f in fns:
        size = getattr(f, "_cache_size", None)
        if size is not None:
            total += size()
    return total


def build_round_program(client_init, client_step, extract,
                        wire_transform=None, fedavg=True):
    """Compile a full FL round into one jit'd program.

    client_init(broadcast) -> carry          (per-client local state)
    client_step(carry, batch, key, lr, broadcast) -> (carry, loss)
    extract(carry) -> pytree to aggregate
    wire_transform(stacked_outs, broadcast, residuals)
        -> (decoded_stacked, new_residuals, clip_scales)
                                             (optional transport hook)
    fedavg=False skips the fused aggregation and returns the (decoded)
    client-stacked trees instead — the buffered-async round policy holds
    individual updates across rounds and averages them itself.

    The returned function has signature

        round(broadcast, shards, batch_idx, step_keys, valid, weights, lr)
          -> (aggregated_tree, (C,) last-step losses)

    or, when ``wire_transform`` is given, an extra trailing ``residuals``
    argument plus two extra results (new residuals and the (C,) DP clip
    scales): each client's extracted tree is packed onto the wire,
    DP-clipped when the transport carries a privacy engine,
    encoded/decoded by the transport codec (threading per-client
    error-feedback residuals through the program), and FedAvg consumes the
    *decoded* trees — the codec's quantization/sparsification error
    propagates into the aggregated model exactly as it would in a real
    deployment.

    ``broadcast`` is shared across clients (global state, alignment
    context), every leaf of ``shards`` is ``(C, n_max, ...)``, ``batch_idx``
    is ``(C, T, B)`` shard-local gather indices, ``step_keys`` is
    ``(C, T, 2)`` and ``valid`` is ``(C, T)``. Steps with ``valid=False``
    still execute (uniform trip count under vmap) but their state update is
    discarded, so padding never changes the result.
    """
    def run_clients(broadcast, shards, batch_idx, step_keys, valid, lr):
        def one_client(shard, idx, keys, ok):
            def body(carry, xs):
                c, last = carry
                i, k, v = xs
                batch = jax.tree.map(lambda a: a[i], shard)
                nc, loss = client_step(c, batch, k, lr, broadcast)
                keep = functools.partial(jnp.where, v)
                return (jax.tree.map(keep, nc, c),
                        jnp.where(v, loss, last)), None

            carry0 = (client_init(broadcast), jnp.float32(0.0))
            (c, last), _ = jax.lax.scan(body, carry0, (idx, keys, ok))
            return extract(c), last

        return jax.vmap(one_client)(shards, batch_idx, step_keys, valid)

    if wire_transform is None:
        def round_fn(broadcast, shards, batch_idx, step_keys, valid,
                     weights, lr):
            outs, losses = run_clients(broadcast, shards, batch_idx,
                                       step_keys, valid, lr)
            if not fedavg:
                return outs, losses
            return aggregate.fedavg_stacked(outs, weights), losses
    else:
        def round_fn(broadcast, shards, batch_idx, step_keys, valid,
                     weights, lr, residuals):
            outs, losses = run_clients(broadcast, shards, batch_idx,
                                       step_keys, valid, lr)
            decoded, new_res, scales = wire_transform(outs, broadcast,
                                                      residuals)
            if not fedavg:
                return decoded, losses, new_res, scales
            return (aggregate.fedavg_stacked(decoded, weights), losses,
                    new_res, scales)

    return jax.jit(round_fn)


class SequentialEngine:
    """Reference engine: the seed driver's per-client Python loop."""

    name = "sequential"

    def __init__(self, *, encoder, ssl_cfg, opt, fl, train_cfg, images,
                 client_indices, transport=None, obs=None):
        self.encoder, self.ssl_cfg, self.opt = encoder, ssl_cfg, opt
        self.fl, self.train_cfg = fl, train_cfg
        self.images, self.client_indices = images, client_indices
        self.counts = [len(ix) for ix in client_indices]
        self.transport = transport or transport_mod.Transport("fp32")
        self.obs = obs if obs is not None else NOOP_OBS
        self._steps: Dict[tuple, object] = {}

    def compile_cache_size(self) -> int:
        return jit_cache_entries(self._steps.values())

    def _step(self, plan):
        sig = (plan.sub_layers, plan.active_from, plan.align,
               plan.depth_dropout)
        if sig not in self._steps:
            self._steps[sig] = client_mod.make_local_step(
                self.encoder, self.ssl_cfg, self.opt,
                sub_layers=plan.sub_layers, active_from=plan.active_from,
                align=plan.align, depth_dropout=plan.depth_dropout)
        return self._steps[sig]

    def lower_round(self, plan, *, clients: int = 1):
        """AOT-lower this engine's compiled unit for ``plan`` with
        abstract inputs: the jit'd per-batch local step (``clients`` is
        accepted for signature parity with the vmap engine and ignored —
        the sequential unit is per-client by construction). The resource
        observatory reads ``cost_analysis``/``memory_analysis`` off the
        result; one program run = one local step over one batch, so
        per-sample FLOPs = flops / batch_size."""
        state, opt_state, img = _abstract_round_inputs(
            self.encoder, self.ssl_cfg, self.opt, self.images,
            self.train_cfg.batch_size)
        return self._step(plan).lower(
            state, opt_state, img,
            jax.ShapeDtypeStruct((2,), jnp.uint32),
            jax.ShapeDtypeStruct((), jnp.float32),
            state["online"]["enc"] if plan.align else None)

    def run_round(self, state, plan, participants, client_keys, lr,
                  global_enc, server_online, collect=False):
        tracer = self.obs.tracer
        step_fn = self._step(plan)
        outs, losses = [], []
        for i, kc in zip(participants, client_keys):
            with tracer.span("client.train", cat="engine",
                             client=int(i)) as sp:
                online_i, m = client_mod.local_train(
                    state, self.images[self.client_indices[i]], step_fn,
                    self.opt, epochs=self.fl.local_epochs,
                    batch_size=self.train_cfg.batch_size, key=kc, lr=lr,
                    global_enc=global_enc)
                outs.append(online_i)
                losses.append(float(m["loss"]))
                sp.set(loss=losses[-1])
        if collect:
            trees, stats = self.transport.decode_uploads(
                server_online, outs, participants, plan,
                ref_online=state["online"])
            return trees, losses, stats
        w = aggregate.client_weights([self.counts[i] for i in participants])
        with tracer.span("aggregate", cat="engine", engine=self.name,
                         clients=len(participants)):
            new_online, stats = self.transport.aggregate_uploads(
                server_online, outs, participants, plan, w,
                ref_online=state["online"])
        return new_online, losses, stats


class VmapEngine:
    """Vectorized engine: one compiled program per (plan signature)."""

    name = "vmap"

    def __init__(self, *, encoder, ssl_cfg, opt, fl, train_cfg, images,
                 client_indices, transport=None, obs=None):
        self.encoder, self.ssl_cfg, self.opt = encoder, ssl_cfg, opt
        self.fl, self.train_cfg = fl, train_cfg
        self.transport = transport or transport_mod.Transport("fp32")
        self.obs = obs if obs is not None else NOOP_OBS
        self.counts = [len(ix) for ix in client_indices]
        bs = train_cfg.batch_size
        if min(self.counts) < bs:
            # the sequential reference also cannot train such a client (it
            # would run zero local steps); fail loudly instead of silently
            # averaging an untrained client with a fabricated 0.0 loss
            raise ValueError(
                f"vmap engine needs every shard >= batch size: smallest "
                f"shard {min(self.counts)} < batch {bs}")
        self.total_steps = fl.local_epochs * max(c // bs
                                                 for c in self.counts)
        # stack padded shard *indices*, not data: per-round gathers pull
        # only the sampled participants' rows from the pool, so device
        # memory scales with clients_per_round x n_max, not N x n_max
        self._pool = images
        self._pad_idx, _ = stack_shards(
            jnp.arange(_pool_len(images)), client_indices)
        # full-participation rounds reuse the same shards/weights
        self._all = list(range(len(self.counts)))
        self._all_weights = aggregate.client_weights(self.counts)
        self._full_shards = None
        self._programs: Dict[tuple, object] = {}

    def compile_cache_size(self) -> int:
        return jit_cache_entries(self._programs.values())

    def _gather(self, idx):
        """(C, n_max) pool indices -> client-stacked shard data."""
        return jax.tree.map(lambda a: a[idx], self._pool)

    def _program(self, plan, spec, fedavg=True):
        sig = (plan.sub_layers, plan.active_from, plan.align,
               plan.depth_dropout, spec.sig, fedavg)
        if sig not in self._programs:
            step = client_mod.make_local_step(
                self.encoder, self.ssl_cfg, self.opt,
                sub_layers=plan.sub_layers, active_from=plan.active_from,
                align=plan.align, depth_dropout=plan.depth_dropout)
            opt = self.opt

            def client_init(bc):
                g = bc["state"]
                st = {"online": jax.tree.map(jnp.asarray, g["online"])}
                if "target" in g:
                    # target branch re-initialized from the downloaded
                    # global model, exactly like local_train
                    st["target"] = {
                        "enc": jax.tree.map(jnp.copy, g["online"]["enc"]),
                        "proj": jax.tree.map(jnp.copy, g["online"]["proj"]),
                    }
                return st, opt.init(st["online"])

            def client_step(carry, batch, key, lr, bc):
                st, os_ = carry
                st, os_, m = step(st, os_, batch, key, lr, bc["global_enc"])
                return (st, os_), m["loss"]

            wire = self.transport.make_wire_transform(spec)
            self._programs[sig] = build_round_program(
                client_init, client_step, lambda c: c[0]["online"],
                wire_transform=lambda outs, bc, res: wire(
                    outs, bc["server"], bc["state"]["online"], res),
                fedavg=fedavg)
        return self._programs[sig]

    def lower_round(self, plan, *, clients: int = 1):
        """AOT-lower the full jit'd round program for ``plan`` with
        abstract inputs: ``clients`` stacked participants at scan trip
        count 1 (XLA's cost analysis counts a rolled loop body once, so
        trip count 1 makes the count exact — one local step per client,
        plus the in-program wire path and FedAvg). Per-sample FLOPs =
        flops / (clients * batch_size)."""
        state, opt_state, img = _abstract_round_inputs(
            self.encoder, self.ssl_cfg, self.opt, self._pool,
            self.train_cfg.batch_size)
        spec = self.transport.plan_specs(state["online"], plan)["upload"]
        C, T, B = clients, 1, self.train_cfg.batch_size
        n_max = self._pad_idx.shape[1]
        residuals = jax.eval_shape(
            lambda: self.transport.gather_residuals(list(range(C)), spec))
        broadcast = {"state": state,
                     "global_enc": (state["online"]["enc"]
                                    if plan.align else None),
                     "server": state["online"]}
        shards = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct((C, n_max) + tuple(a.shape[1:]),
                                           a.dtype), self._pool)
        return self._program(plan, spec).lower(
            broadcast, shards,
            jax.ShapeDtypeStruct((C, T, B), jnp.int32),
            jax.ShapeDtypeStruct((C, T, 2), jnp.uint32),
            jax.ShapeDtypeStruct((C, T), jnp.bool_),
            jax.ShapeDtypeStruct((C,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            residuals)

    def run_round(self, state, plan, participants, client_keys, lr,
                  global_enc, server_online, collect=False):
        bs = self.train_cfg.batch_size
        idxs, keys, valids = [], [], []
        for i, kc in zip(participants, client_keys):
            bi, sk, v = client_mod.replay_batch_plan(
                kc, self.counts[i], self.fl.local_epochs, bs,
                self.total_steps)
            idxs.append(bi)
            keys.append(sk)
            valids.append(v)
        if list(participants) == self._all:
            if self._full_shards is None:
                self._full_shards = self._gather(self._pad_idx)
            shards, w = self._full_shards, self._all_weights
        else:
            pidx = jnp.asarray(np.asarray(participants, np.int32))
            shards = self._gather(self._pad_idx[pidx])
            w = aggregate.client_weights(
                [self.counts[i] for i in participants])
        spec = self.transport.plan_specs(server_online, plan)["upload"]
        residuals = self.transport.gather_residuals(participants, spec)
        # the whole round — every client's local steps, the in-program
        # wire path and FedAvg — is one dispatch, so this span *is* the
        # device time; per-client structure only exists inside XLA
        with self.obs.tracer.span("engine.dispatch", cat="engine",
                                  engine=self.name,
                                  participants=len(participants),
                                  programs=len(self._programs)):
            result, losses, new_res, scales = self._program(
                plan, spec, fedavg=not collect)(
                {"state": state, "global_enc": global_enc,
                 "server": server_online}, shards,
                jnp.stack(idxs), jnp.stack(keys),
                jnp.asarray(np.stack(valids)), w, jnp.float32(lr),
                residuals)
        self.transport.store_residuals(participants, spec, new_res)
        if collect:
            # unstack the decoded client axis into per-client trees (the
            # async policy holds them individually across rounds)
            result = [jax.tree.map(lambda a, i=i: a[i], result)
                      for i in range(len(participants))]
        stats = dict(self.transport.upload_stats(spec))
        stats["clip_fraction"] = float(
            np.mean(np.asarray(scales, np.float32) < 1.0))
        return result, [float(x) for x in np.asarray(losses)], stats


def make_engine(name: str, **kw):
    if name == "sequential":
        return SequentialEngine(**kw)
    if name == "vmap":
        return VmapEngine(**kw)
    raise ValueError(f"unknown engine '{name}'; one of {ENGINES}")
