"""Pairwise-mask additive secure aggregation over the flat wire payload.

Bonawitz et al. 2017 shape: every surviving client pair (a, b), a < b,
derives the same mask vector ``m_ab`` from a shared per-round seed; the
lower id adds it to its (weighted, fixed-point) payload, the higher id
subtracts it. Each individual masked message is uniformly random, but the
masks telescope out of the sum, so the server recovers exactly

  Σ_i  fix(w_i · x_i)

and nothing else. Cancellation must be *bit-exact*, which floats cannot
promise (rounding of ``x + m - m`` depends on the magnitude of ``m``), so
payloads ride the wire as two's-complement fixed point in uint64:

  q = round(w · x · 2^f)   (mod 2^64),   f = ``fraction_bits``

where modular uint64 addition is associative and exact — masked and
unmasked sums agree to the bit (the property test in
``tests/test_privacy.py`` checks it across the vit/xlstm/zamba leaf
families). Dequantization back to fp32 costs one rounding of 2^-f per
element per client (f = 40 ⇒ ~1e-12), the measured gap between
secure-aggregated and float FedAvg training.

Dropouts: the real protocol reconstructs dropped clients' mask shares via
secret sharing. This simulation uses the documented *survivor-set
re-masking* alternative instead: masks are derived at aggregation time
over exactly the set of updates entering the sum, which composes cleanly
with the fleet simulator — the deadline policy decides its survivor set
before training, and the buffered-async policy masks over each buffer
flush's arrival set (see docs/privacy.md for the threat-model caveat).

All of this is host-side numpy: the transport's per-client codec path
(including error-feedback residuals) runs unchanged, and masking wraps
the decoded payloads at the aggregation boundary.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

MASK_DTYPE = np.uint64
MASK_ITEMSIZE = np.dtype(MASK_DTYPE).itemsize      # 8 bytes/element


class SecureAggregator:
    """Fixed-point pairwise masking over flat fp32 payloads.

    ``fraction_bits`` sets the quantization step 2^-f; ``value_range``
    clamps |w·x| before quantization so the headroom analysis holds:
    with f = 40 and R = 256 each term is < 2^48, leaving room for ~2^15
    clients in the int64 sum before overflow.
    """

    def __init__(self, fraction_bits: int = 40, value_range: float = 256.0):
        if not (1 <= fraction_bits <= 52):
            # 2^f must stay exactly representable in the float64 staging
            raise ValueError(
                f"fraction_bits must be in [1, 52]: {fraction_bits}")
        if value_range <= 0:
            raise ValueError(f"value_range must be > 0: {value_range}")
        self.fraction_bits = int(fraction_bits)
        self.value_range = float(value_range)
        self._scale = float(2 ** fraction_bits)

    # -- fixed point --------------------------------------------------------
    def quantize(self, flat, weight: float) -> np.ndarray:
        """fp32 payload -> weighted two's-complement fixed point (uint64)."""
        x = np.asarray(flat, np.float64) * float(weight)
        x = np.clip(x, -self.value_range, self.value_range)
        return np.rint(x * self._scale).astype(np.int64).astype(MASK_DTYPE)

    def dequantize(self, acc: np.ndarray) -> np.ndarray:
        """uint64 modular sum -> fp32 (int64 view restores the sign)."""
        return (acc.view(np.int64).astype(np.float64)
                / self._scale).astype(np.float32)

    # -- masks --------------------------------------------------------------
    @staticmethod
    def pair_mask(seed: Sequence[int], a: int, b: int,
                  n: int) -> np.ndarray:
        """The shared mask for client pair (a, b): full-range uint64 drawn
        from a PRG keyed on (round seed, min id, max id) — both endpoints
        derive the identical vector."""
        if a == b:
            raise ValueError("a client does not mask against itself")
        lo, hi = (a, b) if a < b else (b, a)
        rng = np.random.default_rng([*(int(s) for s in seed),
                                     int(lo), int(hi)])
        return rng.integers(0, np.iinfo(MASK_DTYPE).max, size=n,
                            dtype=MASK_DTYPE, endpoint=True)

    def mask_payload(self, q: np.ndarray, client_id: int,
                     survivors: Sequence[int], seed: Sequence[int],
                     _cache: Dict[Tuple[int, int], np.ndarray] = None
                     ) -> np.ndarray:
        """One client's wire message: fixed-point payload plus/minus the
        pairwise masks against every *other* survivor (mod 2^64)."""
        y = q.copy()
        cid = int(client_id)
        for other in survivors:
            o = int(other)
            if o == cid:
                continue
            pair = (min(cid, o), max(cid, o))
            if _cache is not None and pair in _cache:
                m = _cache[pair]
            else:
                m = self.pair_mask(seed, cid, o, q.shape[0])
                if _cache is not None:
                    _cache[pair] = m
            if cid < o:
                y += m
            else:
                y -= m
        return y

    # -- aggregation --------------------------------------------------------
    def aggregate(self, flats, weights, client_ids, seed: Sequence[int],
                  *, mask: bool = True) -> np.ndarray:
        """Weighted FedAvg sum through the masked fixed-point pipeline.

        ``mask=False`` runs the identical fixed-point path without masks —
        the reference the bit-identity tests compare against (and the
        proof that any difference would come from the masks alone).
        Returns the fp32 flat aggregate Σ_i w_i · x_i.
        """
        ids = [int(c) for c in client_ids]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate client ids in survivor set: {ids}")
        if len(flats) != len(ids) or len(list(weights)) != len(ids):
            raise ValueError("flats / weights / client_ids length mismatch")
        if not flats:
            raise ValueError("nothing to aggregate")
        n = int(np.asarray(flats[0]).shape[0])
        acc = np.zeros(n, MASK_DTYPE)
        cache: Dict[Tuple[int, int], np.ndarray] = {}
        for flat, w, cid in zip(flats, weights, ids):
            q = self.quantize(flat, float(w))
            if mask:
                q = self.mask_payload(q, cid, ids, seed, _cache=cache)
            acc += q
        return self.dequantize(acc)

    def masked_bytes(self, total: int) -> int:
        """Wire size of one client's masked payload: uint64 per element."""
        return int(total) * MASK_ITEMSIZE
