"""(ε, δ) accounting for client-level DP-FedAvg via Rényi DP.

Every round the server releases one Gaussian-mechanism output: the
clipped, weighted client-update mean plus N(0, σ²) noise with
σ = z·C·max_w (``repro.privacy.dp``), whose client-level L2 sensitivity
is bounded by C·max_w — so the *effective* noise multiplier is exactly
``z``, independent of the round's weights. Rounds compose in RDP space:

  rdp_T(α) = Σ_t rdp(q_t, z, α)

with ``q_t = |cohort_t| / num_clients`` the round's sampling fraction
(subsampling amplification). The per-round term is the subsampled
Gaussian mechanism RDP at integer orders α ≥ 2 (Mironov, Talwar & Zhang
2019, "Rényi Differential Privacy of the Sampled Gaussian Mechanism",
eq. for integer α — a binomial sum, exact, evaluated in log space), with
the q=1 closed form α/(2z²) (Mironov 2017, Table II). The conversion to
(ε, δ) is Mironov 2017, Proposition 3:

  ε(δ) = min_α  rdp_T(α) + log(1/δ) / (α - 1)

All arithmetic is host-side Python/numpy — the accountant never touches
the training chain. ``z = 0`` (or a non-finite clip with noise off)
yields ε = ∞: without calibrated noise there is no DP guarantee, and the
driver records that honestly rather than omitting the field.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

# Integer Rényi orders. Dense low range (where subsampled mechanisms
# minimize) plus sparse high orders (where the q=1 Gaussian mechanism
# with small log(1/δ)/(α-1) tails minimizes).
DEFAULT_ORDERS: Tuple[int, ...] = tuple(range(2, 64)) + (
    80, 96, 128, 192, 256, 384, 512)


def _log_binom(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1)
            - math.lgamma(n - k + 1))


def _logsumexp(xs: Sequence[float]) -> float:
    m = max(xs)
    if m == -math.inf:
        return -math.inf
    return m + math.log(sum(math.exp(x - m) for x in xs))


def rdp_sampled_gaussian(q: float, sigma: float, alpha: int) -> float:
    """RDP of one step of the Poisson-subsampled Gaussian mechanism with
    sampling fraction ``q`` and noise multiplier ``sigma`` at integer
    order ``alpha`` >= 2 — exact (Mironov et al. 2019):

      rdp(α) = 1/(α-1) · log Σ_{k=0..α} C(α,k) (1-q)^{α-k} q^k
                               · exp(k(k-1) / (2σ²))

    Closed forms: q=0 → 0 (nothing released about anyone),
    q=1 → α/(2σ²) (plain Gaussian mechanism), σ=0 → ∞.
    """
    if not isinstance(alpha, int) or alpha < 2:
        raise ValueError(f"integer alpha >= 2 required: {alpha!r}")
    if not (0.0 <= q <= 1.0):
        raise ValueError(f"sampling fraction must be in [0, 1]: {q}")
    if q == 0.0:
        return 0.0
    if sigma <= 0.0:
        return math.inf
    if q == 1.0:
        return alpha / (2.0 * sigma * sigma)
    terms = []
    for k in range(alpha + 1):
        log_coef = (_log_binom(alpha, k)
                    + (alpha - k) * math.log1p(-q)
                    + (k * math.log(q) if k else 0.0))
        terms.append(log_coef + k * (k - 1) / (2.0 * sigma * sigma))
    return _logsumexp(terms) / (alpha - 1)


def rdp_to_epsilon(rdp: Sequence[float], orders: Sequence[int],
                   delta: float) -> float:
    """Mironov 2017, Prop. 3: ε = min_α rdp(α) + log(1/δ)/(α-1)."""
    if not (0.0 < delta < 1.0):
        raise ValueError(f"delta must be in (0, 1): {delta}")
    log_inv = math.log(1.0 / delta)
    return min(r + log_inv / (a - 1) for r, a in zip(rdp, orders))


class RDPAccountant:
    """Cumulative RDP ledger for one FL run.

    One ``observe_round(q)`` call per communication round; ``epsilon``
    converts the running ledger to an (ε, δ) guarantee at any time — the
    driver calls it every round to fill ``FLHistory.epsilon`` and enforce
    ``--dp-epsilon-budget``.
    """

    def __init__(self, noise_multiplier: float,
                 orders: Sequence[int] = DEFAULT_ORDERS):
        if noise_multiplier < 0.0:
            raise ValueError(
                f"noise multiplier must be >= 0: {noise_multiplier}")
        self.noise_multiplier = float(noise_multiplier)
        self.orders = tuple(int(a) for a in orders)
        self._rdp = np.zeros(len(self.orders), np.float64)
        self._per_q: Dict[float, np.ndarray] = {}
        self.rounds: List[float] = []     # observed q per round

    def _round_rdp(self, q: float) -> np.ndarray:
        if q not in self._per_q:
            self._per_q[q] = np.asarray(
                [rdp_sampled_gaussian(q, self.noise_multiplier, a)
                 for a in self.orders], np.float64)
        return self._per_q[q]

    def observe_round(self, q: float) -> None:
        """Account one round with sampling fraction ``q``."""
        self.rounds.append(float(q))
        if self.noise_multiplier > 0.0:
            self._rdp = self._rdp + self._round_rdp(float(q))

    def epsilon(self, delta: float) -> float:
        """Cumulative ε at ``delta`` over every observed round."""
        if not self.rounds:
            return 0.0
        if self.noise_multiplier <= 0.0:
            return math.inf
        return rdp_to_epsilon(self._rdp, self.orders, delta)


def compute_epsilon(q: float, noise_multiplier: float, steps: int,
                    delta: float,
                    orders: Sequence[int] = DEFAULT_ORDERS) -> float:
    """ε after ``steps`` identical rounds — the closed-loop form the
    reference-value tests pin against."""
    acct = RDPAccountant(noise_multiplier, orders)
    for _ in range(steps):
        acct.observe_round(q)
    return acct.epsilon(delta)
