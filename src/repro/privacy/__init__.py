"""Privacy subsystem: client-level DP-FedAvg, RDP accounting and
pairwise-mask secure aggregation over the wire transport's flat stage
payloads. See docs/privacy.md.

  dp          PrivacyConfig / PrivacyEngine — update clipping (shared by
              both round engines and both wire paths), calibrated server
              noise, the per-round RNG stream, secure-FedAvg entry points.
  accountant  Rényi-DP composition with subsampling amplification and the
              (ε, δ) conversion (``FLHistory.epsilon``).
  secure_agg  fixed-point pairwise masking that cancels bit-exactly in
              the FedAvg sum.
"""
from repro.privacy.accountant import (DEFAULT_ORDERS, RDPAccountant,
                                      compute_epsilon,
                                      rdp_sampled_gaussian, rdp_to_epsilon)
from repro.privacy.dp import (PRIVACY_STREAM, PrivacyConfig, PrivacyEngine,
                              make_privacy)
from repro.privacy.secure_agg import MASK_ITEMSIZE, SecureAggregator

__all__ = [
    "DEFAULT_ORDERS", "MASK_ITEMSIZE", "PRIVACY_STREAM", "PrivacyConfig",
    "PrivacyEngine", "RDPAccountant", "SecureAggregator", "compute_epsilon",
    "make_privacy", "rdp_sampled_gaussian", "rdp_to_epsilon",
]
