"""Client-level DP-FedAvg + the privacy engine the FL stack threads.

DP-FedAvg (McMahan et al. 2018) at client granularity, expressed over the
transport's flat stage payloads:

  clip    each client's update Δ = payload(trained) - payload(downloaded)
          is global-norm clipped to C *before* the wire codec, as
          θ_ref + min(1, C/‖Δ‖)·Δ — so delta codecs (topk) sparsify the
          clipped delta and cast/quantize codecs ship the clipped model.
          The transport owns this step (``Transport._upload_one``), which
          is what makes the two round engines agree by construction: the
          vmap engine vmaps the very same function inside its jit'd round
          program, the sequential engine jits it per client, and the
          pallas wire path mirrors it in numpy (``clip_host``).
  noise   one server-side Gaussian draw per round on the *aggregated*
          payload: σ = z · C · max_i w_i. The FedAvg mean's client-level
          L2 sensitivity is max_i w_i · C (swap one client's clipped
          update), so the effective noise multiplier seen by the
          accountant is exactly ``z`` for any weighting — uniform weights
          recover the familiar z·C/m.
  account ``repro.privacy.accountant`` composes rounds in RDP space with
          subsampling amplification q = |cohort| / num_clients.

Exactness contracts (tested): with clip = ∞ the scale is exactly 1.0 and
the payload passes through *bit-identically* (a ``where`` on scale < 1,
never ``ref + 1.0·Δ``, which would re-round); with z = 0 the noise step
is statically skipped, so DP-mode plumbing alone never perturbs training.

Secure aggregation (``cfg.secure_agg``) swaps FedAvg for the pairwise-
masked fixed-point sum in ``repro.privacy.secure_agg``; the engines'
``collect=True`` per-client-tree mode feeds it.

RNG: the driver forks one dedicated stream off the run key with
``jax.random.fold_in(key, PRIVACY_STREAM)`` — fold_in does not consume
from the key, so the main chain (init, sampling, client keys,
calibration) is untouched and DP-off runs are byte-identical to
pre-privacy behavior. Per round the stream is folded again on the round
index and split into (noise key, mask seed).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.privacy.accountant import RDPAccountant
from repro.privacy.secure_agg import SecureAggregator

# fold_in tag for the dedicated privacy RNG stream (arbitrary constant,
# fixed forever: changing it changes every seeded DP run)
PRIVACY_STREAM = 0x5EC7E7

_NORM_FLOOR = 1e-12      # guards C/‖Δ‖ when the update is exactly zero


@dataclass(frozen=True)
class PrivacyConfig:
    """Knobs for the privacy subsystem (all off by default).

    clip              L2 clip C on each client's stage-payload update;
                      0 disables DP entirely, ``inf`` runs the clipping
                      machinery as an exact pass-through (parity mode).
    noise_multiplier  z; server noise σ = z·C·max_w. Requires finite
                      clip > 0.
    delta             δ of the reported (ε, δ) guarantee.
    epsilon_budget    hard stop: training halts once cumulative ε
                      exceeds this (0 = unlimited).
    secure_agg        pairwise-mask fixed-point aggregation.
    fraction_bits / mask_range   fixed-point format (secure_agg.py).
    """
    clip: float = 0.0
    noise_multiplier: float = 0.0
    delta: float = 1e-5
    epsilon_budget: float = 0.0
    secure_agg: bool = False
    fraction_bits: int = 40
    mask_range: float = 256.0


class PrivacyEngine:
    """One per FL run: owns the accountant, the clip functions both wire
    engines share, the per-spec noise programs and the secure aggregator."""

    def __init__(self, cfg: PrivacyConfig):
        if cfg.clip < 0.0:
            raise ValueError(f"--dp-clip must be >= 0: {cfg.clip}")
        if cfg.noise_multiplier < 0.0:
            raise ValueError(f"--dp-noise-multiplier must be >= 0: "
                             f"{cfg.noise_multiplier}")
        if cfg.noise_multiplier > 0.0 and not (
                cfg.clip > 0.0 and math.isfinite(cfg.clip)):
            raise ValueError(
                "noise calibration needs a finite --dp-clip > 0: "
                f"sigma = z*C*max_w is unbounded with clip={cfg.clip}")
        if not (0.0 < cfg.delta < 1.0):
            raise ValueError(f"--dp-delta must be in (0, 1): {cfg.delta}")
        self.cfg = cfg
        self.accountant = RDPAccountant(cfg.noise_multiplier)
        self.masker = SecureAggregator(cfg.fraction_bits, cfg.mask_range)
        self._noise_fns: Dict[Tuple, object] = {}

    # -- mode flags ---------------------------------------------------------
    @property
    def dp(self) -> bool:
        """Clipping (and therefore DP bookkeeping) is active."""
        return self.cfg.clip > 0.0

    @property
    def noise_enabled(self) -> bool:
        return self.cfg.noise_multiplier > 0.0

    # -- clipping (both wire engines) ---------------------------------------
    def clip_jax(self, flat, ref_flat):
        """Pure-JAX clip of the payload update: returns (clipped payload,
        scale). scale == 1.0 (clip >= norm) passes ``flat`` through the
        ``where`` untouched — bit-exact, including at clip = ∞."""
        delta = flat - ref_flat
        nrm = jnp.sqrt(jnp.sum(delta * delta))
        scale = jnp.minimum(jnp.float32(1.0),
                            jnp.float32(self.cfg.clip)
                            / jnp.maximum(nrm, _NORM_FLOOR))
        return jnp.where(scale < 1.0, ref_flat + scale * delta, flat), scale

    def clip_host(self, flat, ref_flat):
        """Numpy mirror for the pallas (host) wire path. The no-clip
        branch returns ``flat`` itself (possibly a pooled wire buffer)
        untouched."""
        f32 = np.asarray(flat, np.float32)
        delta = f32 - np.asarray(ref_flat, np.float32)
        nrm = float(np.sqrt(np.sum(delta * delta, dtype=np.float32)))
        scale = min(1.0, self.cfg.clip / max(nrm, _NORM_FLOOR))
        if scale >= 1.0:
            return flat, np.float32(1.0)
        return (np.asarray(ref_flat, np.float32)
                + np.float32(scale) * delta), np.float32(scale)

    # -- server noise -------------------------------------------------------
    def sigma(self, max_weight: float) -> float:
        """Gaussian σ on the aggregated payload for this round's maximum
        FedAvg weight (the mean's per-client sensitivity is C·max_w)."""
        if not self.noise_enabled:
            return 0.0
        return self.cfg.noise_multiplier * self.cfg.clip * float(max_weight)

    def _noise_fn(self, spec):
        if spec.sig not in self._noise_fns:
            from repro.federated import transport as transport_mod

            def fn(tree, flat, key, sig):
                noise = sig * jax.random.normal(key, (spec.total,),
                                                transport_mod.WIRE_DTYPE)
                return transport_mod.unpack_stage_payload(
                    tree, jnp.asarray(flat, transport_mod.WIRE_DTYPE)
                    + noise, spec)

            self._noise_fns[spec.sig] = jax.jit(fn)
        return self._noise_fns[spec.sig]

    def add_noise(self, tree, spec, transport, key, sigma: float):
        """Add N(0, σ²) over the payload slice of ``tree`` (leaves outside
        the payload are untouched — they never left the server). σ = 0 is
        a static skip, so z = 0 cannot perturb a single bit."""
        if sigma == 0.0:
            return tree
        flat = transport._pack_fn(spec)(tree)
        return self._noise_fn(spec)(tree, flat, key, jnp.float32(sigma))

    # -- secure aggregation -------------------------------------------------
    def secure_fedavg(self, trees, weights, client_ids, *, spec, transport,
                      base, seed: Sequence[int], mask: bool = True):
        """Masked fixed-point FedAvg over decoded per-client trees: pack
        each onto the payload, mask-and-sum in uint64, unpack the
        aggregate onto ``base`` (the server keeps its own copy of leaves
        outside the payload, exactly like the unmasked upload path)."""
        from repro.federated import transport as transport_mod
        pack = transport._pack_fn(spec)
        flats = [np.asarray(pack(t), np.float32) for t in trees]
        agg = self.masker.aggregate(
            flats, [float(w) for w in weights],
            [int(c) for c in client_ids], seed, mask=mask)
        return transport_mod.unpack_stage_payload(
            base, jnp.asarray(agg), spec)

    def make_secure_agg_fn(self, transport, spec, base, seed):
        """Aggregation closure for the buffered-async policy: masks are
        derived over each flush's arrival set (survivor-set re-masking)."""
        def agg_fn(trees, weights, client_ids):
            return self.secure_fedavg(trees, weights, client_ids,
                                      spec=spec, transport=transport,
                                      base=base, seed=seed)
        return agg_fn

    def secure_overhead_bytes(self, spec, codec_wire_bytes: int) -> int:
        """Per-client wire overhead of masking this payload: the uint64
        masked residue replaces the codec's wire format."""
        if not self.cfg.secure_agg:
            return 0
        return max(0, self.masker.masked_bytes(spec.total)
                   - int(codec_wire_bytes))

    # -- per-round RNG ------------------------------------------------------
    @staticmethod
    def fork_stream(key):
        """The run's dedicated privacy stream (driver calls this once)."""
        return jax.random.fold_in(key, PRIVACY_STREAM)

    @staticmethod
    def round_keys(stream_key, round_idx: int):
        """(noise key, mask seed ints) for one round, independent of the
        main training chain and of each other."""
        k = jax.random.fold_in(stream_key, round_idx)
        k_noise, k_mask = jax.random.split(k)
        seed = tuple(int(x) for x in np.asarray(k_mask).ravel())
        return k_noise, seed


def make_privacy(privacy) -> Optional[PrivacyEngine]:
    """None / PrivacyConfig / PrivacyEngine -> engine or None (disabled).

    A config with every mechanism off maps to None so the driver's fast
    path stays literally unchanged; noise without clipping is rejected
    here rather than silently un-calibrated.
    """
    if privacy is None:
        return None
    if isinstance(privacy, PrivacyEngine):
        return privacy
    if not isinstance(privacy, PrivacyConfig):
        raise TypeError(f"privacy must be a PrivacyConfig or "
                        f"PrivacyEngine: {type(privacy).__name__}")
    if privacy.clip == 0.0 and not privacy.secure_agg:
        if privacy.noise_multiplier > 0.0:
            raise ValueError("noise calibration needs a finite "
                             "--dp-clip > 0 (sigma = z*C*max_w)")
        return None
    return PrivacyEngine(privacy)
