"""Encoder-decoder transformer backbone (Seamless-M4T medium style).

The speech/multimodal frontend (mel-spectrogram + conv feature extractor) is
a STUB per the assignment carve-out: the encoder consumes precomputed frame
embeddings (B, T_frames, d). The text decoder is a standard causal
transformer with cross-attention to the encoder memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models import scan_cfg
from repro.models.layers.init import embed_init
from repro.models.lm import xent_loss, _stacked_init, _slice_stack, _fix_pos

import functools


def init_encdec(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dec_layers = cfg.dec_layers or cfg.num_layers
    return {
        "embed": embed_init(k1, (cfg.vocab_size, cfg.d_model), dt),
        "enc_blocks": _stacked_init(k2, cfg, "enc", cfg.num_layers),
        "enc_ln": B.rmsnorm_init(cfg.d_model, dt),
        "dec_blocks": _stacked_init(k3, cfg, "cross", dec_layers),
        "final_ln": B.rmsnorm_init(cfg.d_model, dt),
        "lm_head": embed_init(k4, (cfg.d_model, cfg.vocab_size), dt),
    }


def encode(params, frames, cfg, *, sub_layers=None, active_from: int = 0,
           remat: bool = False):
    """frames: (B, T, d) precomputed frontend embeddings."""
    x = frames
    sub = cfg.num_layers if sub_layers is None else sub_layers
    act = max(0, min(active_from, sub))

    def body(carry, p):
        x, aux = carry
        fn = functools.partial(B.block_apply, cfg=cfg, kind="enc")
        if remat:
            fn = jax.checkpoint(fn)
        x, a = fn(p, x)
        return (x, aux + a), None

    if act > 0:
        (x, _), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                 _slice_stack(params["enc_blocks"], 0, act),
                                 unroll=scan_cfg.scan_unroll())
        x = jax.lax.stop_gradient(x)
    if sub > act:
        (x, _), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                 _slice_stack(params["enc_blocks"], act, sub),
                                 unroll=scan_cfg.scan_unroll())
    return B.rmsnorm(params["enc_ln"], x, cfg.norm_eps)


def decode_train(params, tokens, memory, cfg, *, remat: bool = False):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)

    def body(carry, p):
        x, aux = carry
        fn = functools.partial(B.block_apply, cfg=cfg, kind="cross",
                               memory=memory)
        if remat:
            fn = jax.checkpoint(fn)
        x, a = fn(p, x)
        return (x, aux + a), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["dec_blocks"],
                             unroll=scan_cfg.scan_unroll())
    return B.rmsnorm(params["final_ln"], x, cfg.norm_eps)


def encdec_loss(params, batch, cfg, *, sub_layers=None, active_from: int = 0,
                remat: bool = False):
    """batch: {"frontend": (B,T,d), "tokens": (B,S), "labels": (B,S)}."""
    memory = encode(params, batch["frontend"], cfg, sub_layers=sub_layers,
                    active_from=active_from, remat=remat)
    hidden = decode_train(params, batch["tokens"], memory, cfg, remat=remat)
    loss = xent_loss({"embed": params["embed"], "lm_head": params["lm_head"]},
                     hidden, batch["labels"], cfg, batch.get("mask"))
    return loss, {"xent": loss, "aux": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def init_dec_caches(cfg, batch: int, seq_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    dec_layers = cfg.dec_layers or cfg.num_layers
    one = B.block_cache_init(cfg, "cross", batch, seq_len, dtype)
    return _fix_pos(jax.tree.map(
        lambda a: jnp.zeros((dec_layers,) + a.shape, a.dtype), one), cfg)


def decode_step(params, caches, token, pos, memory, cfg):
    """One decoder token against a fixed encoder memory."""
    x = jnp.take(params["embed"], token, axis=0)
    x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)

    def body(x, xs):
        p, c = xs
        x, c2 = B.block_decode(p, x, c, pos, cfg, "cross", memory=memory)
        return x, c2

    x, new_c = jax.lax.scan(body, x, (params["dec_blocks"], caches),
                            unroll=scan_cfg.scan_unroll())
    x = B.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    cdt = jnp.dtype(cfg.compute_dtype)
    logits = (x.astype(cdt) @ params["lm_head"].astype(cdt))
    return logits.astype(jnp.float32), new_c


def prefill(params, frames, tokens, cfg):
    memory = encode(params, frames, cfg)
    hidden = decode_train(params, tokens, memory, cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    logits = (hidden[:, -1:].astype(cdt) @ params["lm_head"].astype(cdt))
    return logits.astype(jnp.float32), memory
