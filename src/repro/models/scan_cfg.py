"""Global scan-unroll switch.

XLA's HLO cost analysis counts a while-loop body ONCE, so a lax.scan over
L layers under-reports FLOPs/bytes by ~L×. The dry-run therefore lowers
with fully-unrolled layer scans (correct roofline terms, larger HLO); real
training keeps scans rolled (small HLO, fast compile).

The sequential time scan inside sLSTM is never unrolled (length = seq_len);
its recurrence FLOPs are analytically small and noted in EXPERIMENTS.md.
"""
UNROLL = False
# Chunk-level scans (SSD / mLSTM chunked cores) stay rolled even when layer
# scans unroll: unrolling L layers x nc chunks x backward makes zamba-class
# graphs intractable to compile. Their flops are re-added analytically
# (repro.roofline.analysis.chunk_loop_correction).
CHUNK_UNROLL = False


def scan_unroll():
    return True if UNROLL else 1


def chunk_unroll():
    return True if CHUNK_UNROLL else 1
