"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed to a rank-`kv_lora_rank` latent c_kv plus a single shared
RoPE key; the cache stores only (c_kv, k_rope) — the memory win that makes
500k-token decode practical. Decode uses the *absorbed* formulation
(w_uk folded into the query, w_uv folded into the output) so per-step compute
is O(rank) per cached token instead of expanding all heads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.init import dense_init
from repro.models.layers.rope import apply_rope
from repro.models.layers.sdpa import sdpa

NEG_INF = -1e30


def mla_init(key, cfg, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 7)
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {
        "w_dkv": dense_init(ks[0], (d, m.kv_lora_rank), dtype),
        "w_kr": dense_init(ks[1], (d, m.qk_rope_head_dim), dtype),
        "w_uk": dense_init(ks[2], (H, m.kv_lora_rank, m.qk_nope_head_dim), dtype),
        "w_uv": dense_init(ks[3], (H, m.kv_lora_rank, m.v_head_dim), dtype),
        "wo": dense_init(ks[5], (H * m.v_head_dim, d), dtype),
    }
    if m.q_lora_rank:
        p["w_dq"] = dense_init(ks[4], (d, m.q_lora_rank), dtype)
        p["w_uq"] = dense_init(ks[6], (m.q_lora_rank, H * qd), dtype)
    else:
        p["w_q"] = dense_init(ks[4], (d, H * qd), dtype)
    return p


def _q_proj(params, xc, cfg, cdt):
    if cfg.mla.q_lora_rank:
        return (xc @ params["w_dq"].astype(cdt)) @ params["w_uq"].astype(cdt)
    return xc @ params["w_q"].astype(cdt)


def _split_q(q, cfg):
    m = cfg.mla
    H = cfg.num_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = q.reshape(*q.shape[:-1], H, qd)
    return q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]


def mla_apply(params, x, cfg, positions=None):
    """Full-sequence MLA (train / prefill). x: (B, S, d).

    The latent is expanded to per-head K/V and attention runs through the
    shared SDPA (streaming for long sequences) — query head dim is
    nope+rope, value head dim is v_head_dim.
    """
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    c_kv = xc @ params["w_dkv"].astype(cdt)                     # (B,S,rank)
    k_rope = (xc @ params["w_kr"].astype(cdt))[:, :, None, :]   # (B,S,1,rd)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    q_nope, q_rope = _split_q(_q_proj(params, xc, cfg, cdt), cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsr,hrn->bshn", c_kv, params["w_uk"].astype(cdt))
    v = jnp.einsum("bsr,hrv->bshv", c_kv, params["w_uv"].astype(cdt))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)              # (B,S,H,nd+rd)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))],
        axis=-1)
    out = sdpa(q, k, v, causal=cfg.causal, window=cfg.window, compute_dtype=cdt)
    y = out.reshape(B, S, H * m.v_head_dim) @ params["wo"].astype(cdt)
    return y.astype(x.dtype)


def init_cache(cfg, batch: int, seq_len: int, dtype):
    m = cfg.mla
    W = min(seq_len, cfg.window) if cfg.window else seq_len
    return {
        "c_kv": jnp.zeros((batch, W, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, W, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((W,), -1, jnp.int32),
    }


def mla_decode(params, x, cache, cur_pos, cfg):
    """Absorbed single-token decode. x: (B, 1, d)."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    cdt = jnp.dtype(cfg.compute_dtype)
    W = cache["c_kv"].shape[1]
    pos = jnp.asarray(cur_pos, jnp.int32)
    xc = x.astype(cdt)
    c_kv_new = xc @ params["w_dkv"].astype(cdt)                  # (B,1,rank)
    k_rope_new = (xc @ params["w_kr"].astype(cdt))[:, :, None, :]
    k_rope_new = apply_rope(k_rope_new, pos[None, None], cfg.rope_theta)[:, :, 0]
    slot = pos % W
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, slot, 0))
    cpos = jax.lax.dynamic_update_slice(cache["pos"], pos[None], (slot,))

    q_nope, q_rope = _split_q(_q_proj(params, xc, cfg, cdt), cfg)  # (B,1,H,*)
    q_rope = apply_rope(q_rope, pos[None, None], cfg.rope_theta)
    # absorb w_uk: q_lat (B,1,H,rank)
    q_lat = jnp.einsum("bshn,hrn->bshr", q_nope, params["w_uk"].astype(cdt))
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_head_dim + m.qk_rope_head_dim))
    logits = (jnp.einsum("bshr,btr->bhst", q_lat, c_kv.astype(cdt))
              + jnp.einsum("bshr,btr->bhst", q_rope, k_rope.astype(cdt)))
    logits = logits.astype(jnp.float32) * scale
    valid = (cpos >= 0) & (cpos <= pos)
    if cfg.window:
        valid = valid & (cpos > pos - cfg.window)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(cdt)
    out_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv.astype(cdt))  # (B,1,H,rank)
    out = jnp.einsum("bshr,hrv->bshv", out_lat, params["w_uv"].astype(cdt))
    y = out.reshape(B, 1, H * m.v_head_dim) @ params["wo"].astype(cdt)
    return y.astype(x.dtype), {"c_kv": c_kv, "k_rope": k_rope, "pos": cpos}
