"""Mamba2 (state-space duality) block — pure-jnp reference implementation.

Chunked SSD algorithm (Dao & Gu, 2024), adapted for TPU: the sequence is
split into chunks of ``chunk_size``; intra-chunk terms are dense matmuls
(MXU-friendly), inter-chunk recurrence is a short ``lax.scan`` over chunk
states. The Pallas kernel in ``repro.kernels.mamba2_scan`` implements the
same math with explicit VMEM tiling and is validated against this module.

Decode is the O(1) recurrent update on the (H, P, N) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.init import dense_init
from repro.models import scan_cfg
from repro.models.layers.norms import rmsnorm, rmsnorm_init


def d_inner(cfg) -> int:
    return cfg.ssm.expand * cfg.d_model


def n_heads(cfg) -> int:
    return d_inner(cfg) // cfg.ssm.head_dim


def mamba2_init(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = d_inner(cfg)
    H = n_heads(cfg)
    conv_ch = di + 2 * s.state_dim
    ks = jax.random.split(key, 4)
    return {
        # in_proj -> [z (di), x (di), B (N), C (N), dt (H)]
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * s.state_dim + H), dtype),
        "w_out": dense_init(ks[1], (di, d), dtype),
        "conv_w": dense_init(ks[2], (s.conv_width, conv_ch), dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((H,), jnp.float32),           # A = -exp(a_log)
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),    # softplus ~ 0.12
        "D": jnp.ones((H,), jnp.float32),
        "norm": rmsnorm_init(di, dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):
        out = out + xp[:, k:k + x.shape[1]] * w[k]
    return out + b


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked selective-state-space scan.

    xh: (B, S, H, P)  inputs per head
    dt: (B, S, H)     positive step sizes
    A:  (H,)          negative decay rates
    Bm, Cm: (B, S, N) input/output projections (single group)
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    a = dt * A  # (B,S,H) log-decay per step (negative)
    # chunk-major layout for a single sequential scan over chunks; only one
    # chunk's O(Q^2) intra-block tensors are ever live (matches the Pallas
    # kernel's grid structure).
    xs = (
        xh.reshape(Bsz, nc, chunk, H, P).transpose(1, 0, 2, 3, 4),
        dt.reshape(Bsz, nc, chunk, H).transpose(1, 0, 2, 3),
        a.reshape(Bsz, nc, chunk, H).transpose(1, 0, 2, 3),
        Bm.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3),
        Cm.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3),
    )
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    i = jnp.arange(chunk)
    causal = (i[:, None] >= i[None, :])

    def step(h, inp):
        x_c, dt_c, a_c, B_c, C_c = inp                     # (B,Q,...)
        cum = jnp.cumsum(a_c, axis=1)                      # (B,Q,H)
        seg = cum[:, :, None, :] - cum[:, None, :, :]      # (B,Q,Q,H)
        L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        CB = jnp.einsum("bin,bjn->bij", C_c, B_c)          # (B,Q,Q)
        M = CB[..., None] * L * dt_c[:, None, :, :]        # (B,Q,Q,H)
        y_diag = jnp.einsum("bijh,bjhp->bihp", M, x_c)
        # contribution of the incoming state
        decay_from_start = jnp.exp(cum)                    # (B,Q,H)
        y_off = jnp.einsum("bin,bhpn->bihp", C_c, h) * \
            decay_from_start[..., None]
        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)       # (B,Q,H)
        w = decay_to_end * dt_c
        st = jnp.einsum("bjh,bjn,bjhp->bhpn", w, B_c, x_c)
        chunk_decay = jnp.exp(jnp.sum(a_c, axis=1))        # (B,H)
        h_new = h * chunk_decay[:, :, None, None] + st
        return h_new, y_diag + y_off

    h_final, ys = jax.lax.scan(step, h0, xs,
                               unroll=scan_cfg.chunk_unroll())  # (nc,B,Q,H,P)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return y, h_final


def mamba2_apply(params, x, cfg, h0=None, conv0=None, *, return_state=False):
    """Full-sequence Mamba2 block. x: (B, S, d)."""
    s = cfg.ssm
    B, S, d = x.shape
    di = d_inner(cfg)
    H = n_heads(cfg)
    N = s.state_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    proj = (x.astype(cdt) @ params["w_in"].astype(cdt)).astype(jnp.float32)
    z, xr, Bm, Cm, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N],
                                  axis=-1)
    conv_in = jnp.concatenate([xr, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"].astype(jnp.float32),
                                        params["conv_b"].astype(jnp.float32)))
    xr, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"])
    A = -jnp.exp(params["a_log"])
    xh = xr.reshape(B, S, H, s.head_dim)
    chunk = min(s.chunk_size, S)
    y, h_final = ssd_chunked(xh, dt, A, Bm, Cm, chunk, h0)
    y = y + xh * params["D"][None, None, :, None]
    y = y.reshape(B, S, di)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y.astype(cdt) @ params["w_out"].astype(cdt)).astype(x.dtype)
    if return_state:
        conv_state = conv_in[:, -(s.conv_width - 1):, :]
        return out, (h_final, conv_state)
    return out


def init_state(cfg, batch: int, dtype):
    s = cfg.ssm
    H = n_heads(cfg)
    conv_ch = d_inner(cfg) + 2 * s.state_dim
    return {
        "h": jnp.zeros((batch, H, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), jnp.float32),
    }


def mamba2_decode(params, x, state, cfg):
    """Single-token recurrent update. x: (B, 1, d)."""
    s = cfg.ssm
    B = x.shape[0]
    di = d_inner(cfg)
    H = n_heads(cfg)
    N = s.state_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    proj = (x[:, 0].astype(cdt) @ params["w_in"].astype(cdt)).astype(jnp.float32)
    z, xr, Bm, Cm, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N],
                                  axis=-1)
    conv_in = jnp.concatenate([xr, Bm, Cm], axis=-1)       # (B, C)
    window = jnp.concatenate([state["conv"], conv_in[:, None]], axis=1)  # (B,K,C)
    w = params["conv_w"].astype(jnp.float32)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w)
                           + params["conv_b"].astype(jnp.float32))
    xr, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"])           # (B, H)
    A = -jnp.exp(params["a_log"])
    xh = xr.reshape(B, H, s.head_dim)
    decay = jnp.exp(dt * A)                                # (B, H)
    h = state["h"] * decay[:, :, None, None] + \
        (dt[:, :, None] * xh)[..., None] * Bm[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, Cm) + xh * params["D"][None, :, None]
    y = y.reshape(B, di)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y.astype(cdt) @ params["w_out"].astype(cdt)).astype(x.dtype)
    new_state = {"h": h, "conv": window[:, 1:]}
    return out[:, None], new_state
