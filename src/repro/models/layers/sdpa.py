"""Scaled dot-product attention: dense and streaming (online-softmax) paths.

The streaming path is the jnp analogue of flash attention: an outer scan over
query chunks and an inner scan over KV chunks carrying (row-max, row-sum,
accumulator). It keeps live memory at O(Qc*Kc) per head instead of O(S*T),
which is what lets 32k-token prefill lower with a sane memory footprint.
(The Pallas kernel in ``repro.kernels.flash_attention`` additionally skips
fully-masked KV blocks; XLA here still computes masked blocks — accounted for
in the roofline notes.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30
STREAM_THRESHOLD = 8192 * 8192  # S*T above which we stream


def _mask(qpos, kpos, causal: bool, window: int):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m = kpos[None, :] <= qpos[:, None]
    if window:
        m = m & (kpos[None, :] > qpos[:, None] - window)
    return m


def sdpa_dense(q, k, v, *, causal: bool, window: int, compute_dtype,
               qpos=None, kpos=None):
    """q:(B,S,H,hd) k:(B,T,H,hd) v:(B,T,H,vd) -> (B,S,H,vd)."""
    S, T = q.shape[1], k.shape[1]
    if qpos is None:
        qpos = jnp.arange(S)
    if kpos is None:
        kpos = jnp.arange(T)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum("bshd,bthd->bhst", q.astype(compute_dtype),
                        k.astype(compute_dtype)).astype(jnp.float32) * scale
    m = _mask(qpos, kpos, causal, window)
    logits = jnp.where(m[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(compute_dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v.astype(compute_dtype))


def sdpa_streaming(q, k, v, *, causal: bool, window: int, compute_dtype,
                   q_chunk: int = 1024, kv_chunk: int = 1024):
    """Online-softmax attention over chunks. Same signature as sdpa_dense."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    vd = v.shape[-1]
    qc = min(q_chunk, S)
    kc = min(kv_chunk, T)
    nq, nk = S // qc, T // kc
    assert S % qc == 0 and T % kc == 0, (S, T, qc, kc)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qr = q.reshape(B, nq, qc, H, hd).transpose(1, 0, 2, 3, 4)

    def q_body(_, qi_and_chunk):
        qi, qblk = qi_and_chunk                     # qblk: (B,qc,H,hd)
        qpos = qi * qc + jnp.arange(qc)

        def kv_body(carry, ki):
            m_run, l_run, acc = carry
            kblk = jax.lax.dynamic_slice_in_dim(k, ki * kc, kc, axis=1)
            vblk = jax.lax.dynamic_slice_in_dim(v, ki * kc, kc, axis=1)
            kpos = ki * kc + jnp.arange(kc)
            logits = jnp.einsum(
                "bshd,bthd->bhst", qblk.astype(compute_dtype),
                kblk.astype(compute_dtype)).astype(jnp.float32) * scale
            msk = _mask(qpos, kpos, causal, window)
            logits = jnp.where(msk[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhst,bthd->bhsd", p.astype(compute_dtype),
                vblk.astype(compute_dtype)).astype(jnp.float32)
            return (m_new, l_new, acc), None

        init = (jnp.full((B, H, qc), NEG_INF, jnp.float32),
                jnp.zeros((B, H, qc), jnp.float32),
                jnp.zeros((B, H, qc, vd), jnp.float32))
        (m_run, l_run, acc), _ = jax.lax.scan(kv_body, init, jnp.arange(nk))
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        return None, out.transpose(0, 2, 1, 3).astype(compute_dtype)  # (B,qc,H,vd)

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), qr))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, vd)


def sdpa(q, k, v, *, causal: bool, window: int, compute_dtype,
         qpos=None, kpos=None):
    S, T = q.shape[1], k.shape[1]
    if S * T > STREAM_THRESHOLD and S > 1 and qpos is None and kpos is None:
        return sdpa_streaming(q, k, v, causal=causal, window=window,
                              compute_dtype=compute_dtype)
    return sdpa_dense(q, k, v, causal=causal, window=window,
                      compute_dtype=compute_dtype, qpos=qpos, kpos=kpos)
