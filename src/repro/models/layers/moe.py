"""Mixture-of-Experts FFN with expert parallelism.

Expert-parallel scheme (TPU-adapted): expert weights are sharded over the
"model" mesh axis; activations are replicated over "model". Each device owns
``E_local`` experts, selects up to ``capacity`` of its shard's tokens per
expert (top-C by router weight — the standard token-dropping formulation),
computes only those FFNs, scatter-adds weighted outputs, and the partial
outputs are summed over the "model" axis (one all-reduce per MoE layer).
Compute per device is E_local*C*ffn — i.e. the *active* FLOPs, never the
dense all-experts product.

Used inside ``shard_map`` by the distributed model (see
``repro.sharding.context``); called directly (e_first=0, no psum) on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.init import dense_init


def moe_init(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    e_ff = m.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.num_experts), dtype, scale=0.1),
        "w_gate": dense_init(ks[1], (m.num_experts, d, e_ff), dtype),
        "w_up": dense_init(ks[2], (m.num_experts, d, e_ff), dtype),
        "w_down": dense_init(ks[3], (m.num_experts, e_ff, d), dtype),
    }
    if m.num_shared_experts:
        sf = m.num_shared_experts * e_ff
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(ks2[0], (d, sf), dtype),
            "w_up": dense_init(ks2[1], (d, sf), dtype),
            "w_down": dense_init(ks2[2], (sf, d), dtype),
        }
    return p


def capacity(num_tokens: int, cfg) -> int:
    m = cfg.moe
    c = int(num_tokens * m.experts_per_token * m.capacity_factor / m.num_experts)
    return max(4, min(num_tokens, c))


def moe_ffn_local(params, x, cfg, e_first, e_local: int, cap: int):
    """Local expert compute for one shard.

    x: (T, d) local tokens (replicated over the model axis by the caller).
    e_first: scalar index of this shard's first expert.
    Returns (partial_out (T, d), aux_metrics) — caller psums partial_out over
    the "model" axis and the aux counters over the "data"+"model" axes.
    """
    m = cfg.moe
    T, d = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    logits = (xc @ params["router"].astype(cdt)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, m.experts_per_token)            # (T, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    # membership weight of this shard's experts for each token: (E_local, T)
    w_e = jnp.zeros((e_local, T), jnp.float32)
    tok = jnp.arange(T)
    for k in range(m.experts_per_token):
        rel = topi[:, k] - e_first
        ok = (rel >= 0) & (rel < e_local)
        rel = jnp.clip(rel, 0, e_local - 1)
        w_e = w_e.at[rel, tok].add(jnp.where(ok, topv[:, k], 0.0))

    selv, seli = jax.lax.top_k(w_e, cap)          # (E_local, C)
    xin = jnp.take(xc, seli.reshape(-1), axis=0).reshape(e_local, cap, d)
    # NB: under shard_map the expert dim of the weights is already the local
    # slice (shape (E_local, ...)).
    wg = params["w_gate"].astype(cdt)
    wu = params["w_up"].astype(cdt)
    wd = params["w_down"].astype(cdt)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, wg)) * \
        jnp.einsum("ecd,edf->ecf", xin, wu)
    y = jnp.einsum("ecf,efd->ecd", h, wd)
    y = y * selv[..., None].astype(cdt)
    out = jnp.zeros((T, d), cdt).at[seli.reshape(-1)].add(
        y.reshape(e_local * cap, d))

    # load-balance aux loss terms (GShard/Switch): mean routed fraction x
    # mean router prob, per expert — computed on the full router output so it
    # is identical on every model shard.
    frac = jnp.mean(
        jax.nn.one_hot(topi, m.num_experts, dtype=jnp.float32), axis=(0, 1))
    mean_p = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(frac * mean_p)
    dropped = jnp.sum(w_e > 0) - jnp.sum(selv > 0)
    return out.astype(x.dtype), {"aux": aux, "dropped": dropped}


def shared_expert_ffn(params, x, cfg):
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    sp = params["shared"]
    h = jax.nn.silu(xc @ sp["w_gate"].astype(cdt)) * (xc @ sp["w_up"].astype(cdt))
    return (h @ sp["w_down"].astype(cdt)).astype(x.dtype)
