"""Grouped-query attention with causal / sliding-window masking and KV cache.

Reference (jnp) path used for lowering & CPU tests; the Pallas flash-attention
kernel in ``repro.kernels.flash_attention`` implements the identical math with
VMEM tiling for TPU and is validated against this module's oracle. Long
sequences automatically take the streaming online-softmax path in
``repro.models.layers.sdpa``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.init import dense_init
from repro.models.layers.rope import apply_rope
from repro.models.layers.sdpa import sdpa

BIG_POS = jnp.int32(2**30)


def attn_init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.num_heads * hd), dtype),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, d), dtype),
    }


def _repeat_kv(k, num_heads):
    rep = num_heads // k.shape[2]
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def attn_apply(params, x, cfg, positions=None, *, return_kv: bool = False):
    """Full-sequence attention (train / prefill). x: (B, S, d)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    q = (xc @ params["wq"].astype(cdt)).reshape(B, S, cfg.num_heads, hd)
    k = (xc @ params["wk"].astype(cdt)).reshape(B, S, cfg.num_kv_heads, hd)
    v = (xc @ params["wv"].astype(cdt)).reshape(B, S, cfg.num_kv_heads, hd)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = sdpa(q, _repeat_kv(k, cfg.num_heads), _repeat_kv(v, cfg.num_heads),
               causal=cfg.causal, window=cfg.window, compute_dtype=cdt)
    y = (out.reshape(B, S, cfg.num_heads * hd) @ params["wo"].astype(cdt))
    y = y.astype(x.dtype)
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# KV cache (ring buffer when sliding-window)
# ---------------------------------------------------------------------------
def cache_size(cfg, seq_len: int) -> int:
    return min(seq_len, cfg.window) if cfg.window else seq_len


def init_cache(cfg, batch: int, seq_len: int, dtype):
    """Per-layer cache leaves; stacked over layers by the model."""
    W = cache_size(cfg, seq_len)
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, W, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, W, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.full((W,), -1, jnp.int32),
    }


def attn_decode(params, x, cache, cur_pos, cfg):
    """Single-token decode. x: (B, 1, d); cur_pos: scalar int32.

    Keys are stored *post-RoPE*, so ring-buffer eviction needs no re-rotation.
    Empty slots carry position 2^30 and are excluded by the causal mask.
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    W = cache["k"].shape[1]
    xc = x.astype(cdt)
    q = (xc @ params["wq"].astype(cdt)).reshape(B, 1, cfg.num_heads, hd)
    k = (xc @ params["wk"].astype(cdt)).reshape(B, 1, cfg.num_kv_heads, hd)
    v = (xc @ params["wv"].astype(cdt)).reshape(B, 1, cfg.num_kv_heads, hd)
    pos = jnp.asarray(cur_pos, jnp.int32)
    q = apply_rope(q, pos[None, None], cfg.rope_theta)
    k = apply_rope(k, pos[None, None], cfg.rope_theta)
    slot = pos % W
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    cpos = jax.lax.dynamic_update_slice(cache["pos"], pos[None], (slot,))
    kpos = jnp.where(cpos >= 0, cpos, BIG_POS)
    out = sdpa(q, _repeat_kv(ck.astype(cdt), cfg.num_heads),
               _repeat_kv(cv.astype(cdt), cfg.num_heads),
               causal=True, window=cfg.window, compute_dtype=cdt,
               qpos=pos[None], kpos=kpos)
    y = (out.reshape(B, 1, cfg.num_heads * hd) @ params["wo"].astype(cdt))
    return y.astype(x.dtype), {"k": ck, "v": cv, "pos": cpos}


# ---------------------------------------------------------------------------
# Cross attention (enc-dec)
# ---------------------------------------------------------------------------
def cross_attn_apply(params, x, memory, cfg):
    """x: (B, S, d) queries; memory: (B, T, d) encoder output (no RoPE)."""
    B, S, _ = x.shape
    T = memory.shape[1]
    hd = cfg.resolved_head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    xc, mc = x.astype(cdt), memory.astype(cdt)
    q = (xc @ params["wq"].astype(cdt)).reshape(B, S, cfg.num_heads, hd)
    k = (mc @ params["wk"].astype(cdt)).reshape(B, T, cfg.num_kv_heads, hd)
    v = (mc @ params["wv"].astype(cdt)).reshape(B, T, cfg.num_kv_heads, hd)
    out = sdpa(q, _repeat_kv(k, cfg.num_heads), _repeat_kv(v, cfg.num_heads),
               causal=False, window=0, compute_dtype=cdt)
    y = (out.reshape(B, S, cfg.num_heads * hd) @ params["wo"].astype(cdt))
    return y.astype(x.dtype)
