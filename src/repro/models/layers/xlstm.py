"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM and sLSTM.

mLSTM: matrix memory C in R^{PxP} per head with exponential input gate and
forget gate — parallelizable over the sequence (decay-masked attention-like
form, used for train/prefill) with an O(1) recurrent decode step.

sLSTM: scalar memory with recurrent (R) weights and exponential gating —
inherently sequential, implemented as ``lax.scan`` over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.init import dense_init
from repro.models import scan_cfg
from repro.models.layers.norms import layernorm, layernorm_init, rmsnorm, rmsnorm_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    di = int(cfg.xlstm.proj_factor * d)
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * di), dtype),     # [x_inner, z gate]
        "w_q": dense_init(ks[1], (di, di), dtype),
        "w_k": dense_init(ks[2], (di, di), dtype),
        "w_v": dense_init(ks[3], (di, di), dtype),
        "w_i": dense_init(ks[4], (di, H), dtype),          # input gate (exp)
        "w_f": dense_init(ks[5], (di, H), dtype),          # forget gate
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),           # init mostly-remember
        "norm": rmsnorm_init(di, dtype),
        "w_down": dense_init(ks[6], (di, d), dtype),
    }


def _mlstm_gates(params, xi):
    logi = (xi @ params["w_i"].astype(jnp.float32)) + params["b_i"]
    logf = (xi @ params["w_f"].astype(jnp.float32)) + params["b_f"]
    return logi, jax.nn.log_sigmoid(logf)                  # log f in (-inf, 0)


def mlstm_apply(params, x, cfg, *, return_state=False, state=None):
    """Parallel (quadratic, decay-masked) form. x: (B, S, d)."""
    d = cfg.d_model
    di = int(cfg.xlstm.proj_factor * d)
    H = cfg.num_heads
    P = di // H
    B, S, _ = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    up = x.astype(cdt) @ params["w_up"].astype(cdt)
    xi, z = jnp.split(up, 2, axis=-1)
    xf = xi.astype(jnp.float32)
    q = (xi @ params["w_q"].astype(cdt)).reshape(B, S, H, P)
    k = (xi @ params["w_k"].astype(cdt)).reshape(B, S, H, P) / jnp.sqrt(P).astype(cdt)
    v = (xi @ params["w_v"].astype(cdt)).reshape(B, S, H, P)
    logi, logf = _mlstm_gates(params, xf)                  # (B,S,H)
    if state is None:
        state = mlstm_init_state(cfg, B)
    if S >= MLSTM_CHUNK and S % MLSTM_CHUNK == 0:
        y, st = _mlstm_chunked_core(
            q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            logi, logf, state, MLSTM_CHUNK)
        y = y.reshape(B, S, di).astype(cdt)
        y = rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
        out = (y @ params["w_down"].astype(cdt)).astype(x.dtype)
        if return_state:
            return out, st
        return out
    F = jnp.cumsum(logf, axis=1)                           # (B,S,H)
    # D_ij = exp(F_i - F_j + i_j) for j<=i, stabilized per row
    dmat = F[:, :, None, :] - F[:, None, :, :] + logi[:, None, :, :]
    idx = jnp.arange(S)
    causal = idx[:, None] >= idx[None, :]
    dmat = jnp.where(causal[None, :, :, None], dmat, NEG_INF)
    m = jnp.max(dmat, axis=2, keepdims=True)               # row max (B,S,1,H)
    D = jnp.exp(dmat - m)                                  # (B,S,S,H)
    qk = jnp.einsum("bihp,bjhp->bijh", q.astype(jnp.float32), k.astype(jnp.float32))
    W = qk * D
    norm = jnp.maximum(jnp.abs(jnp.sum(W, axis=2)), jnp.exp(-m[:, :, 0]))
    y = jnp.einsum("bijh,bjhp->bihp", W, v.astype(jnp.float32)) / norm[..., None]
    y = y.reshape(B, S, di).astype(cdt)
    y = rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = (y @ params["w_down"].astype(cdt)).astype(x.dtype)
    if return_state:
        # build final recurrent state by replaying recurrences (decode handoff)
        st = mlstm_init_state(cfg, B)
        # C_S = sum_j exp(F_S - F_j + i_j) v_j k_j^T ; n_S likewise
        wS = jnp.exp(F[:, -1:, :] - F + logi)              # (B,S,H)
        C = jnp.einsum("bjh,bjhp,bjhq->bhpq", wS, v.astype(jnp.float32),
                       k.astype(jnp.float32))
        n = jnp.einsum("bjh,bjhp->bhp", wS, k.astype(jnp.float32))
        mS = jnp.max(F[:, -1:, :] - F + logi, axis=1)      # crude stabilizer
        st = {"C": C, "n": n, "m": mS}
        return out, st
    return out


def _mlstm_chunked_core(q, k, v, logi, logf, state, chunk: int):
    """Chunkwise-parallel stabilized mLSTM (TFLA-style).

    q,k,v: (B,S,H,P) fp32; logi/logf: (B,S,H). Sequential scan over chunks of
    length `chunk`, carrying the (C, n, m) matrix-memory state. Only one
    chunk's O(Q^2) tensors are live at a time.
    """
    B, S, H, P = q.shape
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    xs = tuple(t.reshape(B, nc, chunk, *t.shape[2:]).transpose(1, 0, 2, 3, 4)
               if t.ndim == 4 else
               t.reshape(B, nc, chunk, H).transpose(1, 0, 2, 3)
               for t in (q, k, v, logi, logf))
    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]

    def step(st, inp):
        qc, kc, vc, ic, fc = inp                          # (B,Q,...)
        F = jnp.cumsum(fc, axis=1)                        # inclusive (B,Q,H)
        # intra-chunk log weights: D_ij = F_i - F_j + i_j (j<=i)
        dmat = F[:, :, None, :] - F[:, None, :, :] + ic[:, None, :, :]
        dmat = jnp.where(causal[None, :, :, None], dmat, NEG_INF)
        # incoming-state log scale per row: F_i + m_in
        inter = F + st["m"][:, None, :]                   # (B,Q,H)
        m_i = jnp.maximum(jnp.max(dmat, axis=2), inter)   # (B,Q,H)
        D = jnp.exp(dmat - m_i[:, :, None, :])            # (B,Q,Q,H)
        w_in = jnp.exp(inter - m_i)                       # (B,Q,H)
        qk = jnp.einsum("bihp,bjhp->bijh", qc, kc)
        W = qk * D
        num = jnp.einsum("bijh,bjhp->bihp", W, vc) + \
            jnp.einsum("bihp,bhpq->bihq", qc * w_in[..., None], st["C"])
        den = jnp.einsum("bijh,bjhp->bih", W, kc) + \
            jnp.einsum("bihp,bhp->bih", qc * w_in[..., None], st["n"])
        norm = jnp.maximum(jnp.abs(den), jnp.exp(-m_i))
        y = num / norm[..., None]
        # state update to end of chunk
        decay_to_end = F[:, -1:, :] - F + ic              # (B,Q,H)
        m_out = jnp.maximum(F[:, -1, :] + st["m"], jnp.max(decay_to_end, axis=1))
        w_st = jnp.exp(decay_to_end - m_out[:, None, :])
        carry_w = jnp.exp(F[:, -1, :] + st["m"] - m_out)
        C = st["C"] * carry_w[..., None, None] + \
            jnp.einsum("bjh,bjhp,bjhq->bhpq", w_st, vc, kc)
        n = st["n"] * carry_w[..., None] + jnp.einsum("bjh,bjhp->bhp", w_st, kc)
        return {"C": C, "n": n, "m": m_out}, y

    st_final, ys = jax.lax.scan(step, state, xs,
                                unroll=scan_cfg.chunk_unroll())
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, st_final


MLSTM_CHUNK = 256


def mlstm_init_state(cfg, batch: int):
    di = int(cfg.xlstm.proj_factor * cfg.d_model)
    H = cfg.num_heads
    P = di // H
    return {
        "C": jnp.zeros((batch, H, P, P), jnp.float32),
        "n": jnp.zeros((batch, H, P), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode(params, x, state, cfg):
    """O(1) recurrent step. x: (B, 1, d)."""
    d = cfg.d_model
    di = int(cfg.xlstm.proj_factor * d)
    H = cfg.num_heads
    P = di // H
    B = x.shape[0]
    cdt = jnp.dtype(cfg.compute_dtype)
    up = x[:, 0].astype(cdt) @ params["w_up"].astype(cdt)
    xi, z = jnp.split(up, 2, axis=-1)
    xf = xi.astype(jnp.float32)
    q = (xi @ params["w_q"].astype(cdt)).reshape(B, H, P).astype(jnp.float32)
    k = ((xi @ params["w_k"].astype(cdt)).reshape(B, H, P) /
         jnp.sqrt(P).astype(cdt)).astype(jnp.float32)
    v = (xi @ params["w_v"].astype(cdt)).reshape(B, H, P).astype(jnp.float32)
    logi, logf = _mlstm_gates(params, xf)                  # (B,H)
    m_new = jnp.maximum(logf + state["m"], logi)
    a = jnp.exp(logf + state["m"] - m_new)
    b = jnp.exp(logi - m_new)
    C = state["C"] * a[..., None, None] + b[..., None, None] * \
        jnp.einsum("bhp,bhq->bhpq", v, k)
    n = state["n"] * a[..., None] + b[..., None] * k
    num = jnp.einsum("bhpq,bhq->bhp", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, q)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, di).astype(cdt)
    y = rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = (y @ params["w_down"].astype(cdt)).astype(x.dtype)
    return out[:, None], {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    P = d // H
    ks = jax.random.split(key, 3)
    return {
        "w": dense_init(ks[0], (d, 4 * d), dtype),          # i,f,z,o pre-acts
        "r": dense_init(ks[1], (H, P, 4 * P), dtype, scale=0.5),  # block-diag recur
        "b": jnp.zeros((4 * d,), jnp.float32),
        "norm": layernorm_init(d, dtype),
        "w_down": dense_init(ks[2], (d, d), dtype),
    }


def slstm_init_state(cfg, batch: int):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(params, xt, st, cfg):
    """xt: (B, 4d) pre-activation from input; st: state dict."""
    d = cfg.d_model
    H = cfg.num_heads
    P = d // H
    B = xt.shape[0]
    hprev = st["h"].reshape(B, H, P)
    rec = jnp.einsum("bhp,hpq->bhq", hprev,
                     params["r"].astype(jnp.float32)).reshape(B, 4 * d)
    pre = xt + rec + params["b"]
    zi, zf, zz, zo = jnp.split(pre, 4, axis=-1)
    logf = jax.nn.log_sigmoid(zf)
    m_new = jnp.maximum(logf + st["m"], zi)
    i = jnp.exp(zi - m_new)
    f = jnp.exp(logf + st["m"] - m_new)
    z = jnp.tanh(zz)
    o = jax.nn.sigmoid(zo)
    c = f * st["c"] + i * z
    n = f * st["n"] + i
    h = o * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply(params, x, cfg, *, return_state=False, state=None):
    """Sequential scan over time. x: (B, S, d)."""
    B, S, d = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    xs = (x.astype(cdt) @ params["w"].astype(cdt)).astype(jnp.float32)
    st0 = state if state is not None else slstm_init_state(cfg, B)

    def step(st, xt):
        st = _slstm_cell(params, xt, st, cfg)
        return st, st["h"]

    st_final, hs = jax.lax.scan(step, st0, xs.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(cdt)                  # (B,S,d)
    y = layernorm(params["norm"], y, cfg.norm_eps)
    out = (y @ params["w_down"].astype(cdt)).astype(x.dtype)
    if return_state:
        return out, st_final
    return out


def slstm_decode(params, x, state, cfg):
    cdt = jnp.dtype(cfg.compute_dtype)
    xt = (x[:, 0].astype(cdt) @ params["w"].astype(cdt)).astype(jnp.float32)
    st = _slstm_cell(params, xt, state, cfg)
    y = layernorm(params["norm"], st["h"].astype(cdt)[:, None], cfg.norm_eps)
    out = (y @ params["w_down"].astype(cdt)).astype(x.dtype)
    return out, st
