"""Normalization layers (functional)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def batchnorm_init(d: int, dtype=jnp.float32):
    # MoCo v3 MLP heads use BN; in our federated simulation we use the
    # batch statistics directly (sync-BN within the jit'd step).
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def batchnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=0, keepdims=True)
    var = jnp.var(xf, axis=0, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)
