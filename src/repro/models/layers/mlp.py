"""Feed-forward layers: SwiGLU / GELU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.init import dense_init


def mlp_init(key, d_model: int, d_ff: int, act: str = "swiglu", dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype),
    }
    if act == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def mlp_apply(params, x, act: str = "swiglu", compute_dtype=jnp.bfloat16):
    xc = x.astype(compute_dtype)
    up = xc @ params["w_up"].astype(compute_dtype)
    if act == "swiglu":
        gate = xc @ params["w_gate"].astype(compute_dtype)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return (h @ params["w_down"].astype(compute_dtype)).astype(x.dtype)
