"""Weight initializers."""
from __future__ import annotations

import numpy as np
import jax


def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init (LeCun-style)."""
    fan_in = shape[0] if len(shape) >= 2 else max(1, shape[-1])
    if len(shape) == 3:            # (experts, d_in, d_out) — fan-in is dim 1
        fan_in = shape[1]
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def embed_init(key, shape, dtype, std: float = 0.02):
    return (jax.random.normal(key, shape) * std).astype(dtype)
