"""Decoder-only language model assembly.

Three topologies, all built from ``repro.models.blocks``:

- "uniform": L identical blocks (dense / moe / mla_moe / mamba / mlstm),
  run as a single ``lax.scan`` over stacked params (O(1) HLO size).
- "zamba":  groups of ``attn_every`` Mamba2 blocks followed by one *shared*
  attention block (Zamba2, arXiv:2411.15242) — outer scan over groups,
  inner scan over the group's Mamba blocks, shared attn weights reused.
- "xlstm":  repeating pattern of (slstm_every-1) mLSTM blocks + 1 sLSTM
  block (arXiv:2405.04517) — outer scan over pattern groups.

Supports the paper's layer-wise / progressive staging: ``sub_layers`` limits
model depth (stage s sub-model), ``active_from`` freezes the prefix with
``stop_gradient`` so XLA builds no backward graph for frozen layers — the
actual compute/memory saving of LW-FedSSL, realized in HLO.

VLM / audio frontends are stubs per the assignment carve-out: callers pass
precomputed patch/frame embeddings which are concatenated ahead of the token
embeddings (``frontend`` input).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models import scan_cfg
from repro.models.layers.init import embed_init

LOSS_CHUNK = 512

# §Perf knob (EXPERIMENTS.md): gold-logit extraction in the chunked loss.
#  "take" — take_along_axis over the vocab dim (paper-faithful baseline;
#           under vocab tensor parallelism XLA all-gathers (B,c,V) logits)
#  "mask" — sum(logits * (iota == label)): stays partitioned, no gather
#  "wgather" — gather label columns of W, dot with hidden: gathers the
#           small (V,d) table instead of (B,c,V) logits
XENT_GOLD_MODE = "take"

# §Perf knob: residual-stream dtype. "param" (baseline) keeps activations
# in the parameter dtype (fp32 at full scale) — every tensor-parallel
# activation collective moves 2x the bytes. "compute" casts the embedded
# stream to compute_dtype (bf16), the standard mixed-precision practice.
ACT_DTYPE = "param"

# §Perf knob: sequence-parallel residual stream (Korthikanti et al.) —
# constrain each block's output to be sharded over ("data","model") on
# (batch, seq): XLA turns TP output all-reduces into reduce-scatter +
# all-gather pairs whose per-device traffic is 16x smaller.
SEQ_SHARD = False

# §Perf knob: rematerialization policy for the per-block checkpoint.
# None = save nothing (recompute everything incl. collective gathers in
# backward); "dots" = save matmul outputs (jax dots_with_no_batch_dims) so
# the backward pass re-does neither the matmuls nor their input gathers.
REMAT_POLICY = None


def _maybe_seq_shard(x):
    if not SEQ_SHARD:
        return x
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(
            x, P("data", "model", None))
    except Exception:
        return x


# ---------------------------------------------------------------------------
# topology plan
# ---------------------------------------------------------------------------
def topology(cfg) -> str:
    if cfg.family == "hybrid":
        return "zamba"
    if cfg.xlstm is not None:
        return "xlstm"
    if cfg.moe is not None and cfg.moe.num_experts > 0 \
            and cfg.moe.moe_every > 1 and cfg.mla is None:
        return "moe_il"           # Llama-4 style 1 MoE : (k-1) dense
    return "uniform"


def uniform_kind(cfg) -> str:
    if cfg.mla is not None:
        return "mla_moe"
    if cfg.moe is not None and cfg.moe.num_experts > 0:
        return "moe"
    if cfg.ssm is not None:
        return "mamba"
    return "dense"


def num_stages(cfg) -> int:
    """Stage granularity of the layer-wise schedule for this topology."""
    topo = topology(cfg)
    if topo == "zamba":
        return cfg.num_layers // cfg.attn_every
    if topo == "moe_il":
        return cfg.num_layers // cfg.moe.moe_every
    if topo == "xlstm":
        return cfg.num_layers // cfg.xlstm.slstm_every if cfg.xlstm.slstm_every \
            else cfg.num_layers
    return cfg.num_layers


def _stacked_init(key, cfg, kind, n, extra_dims=()):
    total = n
    for e in extra_dims:
        total *= e
    keys = jax.random.split(key, total)
    p = jax.vmap(lambda k: B.block_init(k, cfg, kind))(keys)
    if extra_dims:
        p = jax.tree.map(lambda a: a.reshape((n,) + extra_dims + a.shape[1:]), p)
    return p


def init_lm(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    k_embed, k_blocks, k_head, k_shared = jax.random.split(key, 4)
    params = {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dt),
        "final_ln": B.rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(k_head, (cfg.d_model, cfg.vocab_size), dt)
    topo = topology(cfg)
    if topo == "uniform":
        params["blocks"] = _stacked_init(k_blocks, cfg, uniform_kind(cfg),
                                         cfg.num_layers)
    elif topo == "zamba":
        g = cfg.num_layers // cfg.attn_every
        params["blocks"] = _stacked_init(k_blocks, cfg, "mamba", g,
                                         (cfg.attn_every,))
        params["shared_attn"] = B.block_init(k_shared, cfg, "attn_only")
    elif topo == "xlstm":
        per = cfg.xlstm.slstm_every or cfg.num_layers
        g = cfg.num_layers // per
        params["mlstm"] = _stacked_init(k_blocks, cfg, "mlstm", g, (per - 1,))
        params["slstm"] = _stacked_init(k_shared, cfg, "slstm", g)
    elif topo == "moe_il":
        k = cfg.moe.moe_every
        g = cfg.num_layers // k
        params["blocks"] = _stacked_init(k_blocks, cfg, "dense", g, (k - 1,))
        params["moe_blocks"] = _stacked_init(k_shared, cfg, "moe", g)
    return params


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------
def embed(params, tokens, cfg, frontend=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if ACT_DTYPE == "compute":
        x = x.astype(jnp.dtype(cfg.compute_dtype))
    x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    if frontend is not None:
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
    return x


def _head_matrix(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


# ---------------------------------------------------------------------------
# forward (full sequence)
# ---------------------------------------------------------------------------
def _scan_apply(stacked, x, cfg, kind, positions, remat):
    fn = functools.partial(B.block_apply, cfg=cfg, kind=kind, positions=positions)
    if remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if REMAT_POLICY == "dots" else None)
        fn = jax.checkpoint(fn, policy=policy)

    def body(carry, p):
        x, aux = carry
        x, a = fn(p, x)
        return (_maybe_seq_shard(x), aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stacked,
                               unroll=scan_cfg.scan_unroll())
    return x, aux


def _slice_stack(stacked, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], stacked)


def forward_hidden(params, x, cfg, *, sub_layers: Optional[int] = None,
                   active_from: int = 0, remat: bool = False, positions=None):
    """x: (B, S, d) embedded inputs. Returns (hidden, aux_loss).

    sub_layers: stage-s sub-model depth in *stages* (None = full model).
    active_from: stages < active_from run under stop_gradient (frozen).
    """
    topo = topology(cfg)
    aux = jnp.float32(0.0)
    S = num_stages(cfg) if topo != "uniform" else cfg.num_layers
    sub = S if sub_layers is None else sub_layers
    act = max(0, min(active_from, sub))

    if topo == "uniform":
        kind = uniform_kind(cfg)
        if act > 0:
            x, a = _scan_apply(_slice_stack(params["blocks"], 0, act), x, cfg,
                               kind, positions, remat)
            x, aux = jax.lax.stop_gradient(x), aux + jax.lax.stop_gradient(a)
        if sub > act:
            x, a = _scan_apply(_slice_stack(params["blocks"], act, sub), x, cfg,
                               kind, positions, remat)
            aux = aux + a
    elif topo == "zamba":
        def group(x_aux, gp):
            x, aux = x_aux
            x, a = _scan_apply(gp, x, cfg, "mamba", positions, remat)
            x, a2 = B.block_apply(params["shared_attn"], x, cfg, "attn_only",
                                  positions)
            return (x, aux + a + a2), None

        if act > 0:
            (x, aux), _ = jax.lax.scan(
                group, (x, aux), _slice_stack(params["blocks"], 0, act),
                unroll=scan_cfg.scan_unroll())
            x, aux = jax.lax.stop_gradient(x), jax.lax.stop_gradient(aux)
        if sub > act:
            (x, aux), _ = jax.lax.scan(
                group, (x, aux), _slice_stack(params["blocks"], act, sub),
                unroll=scan_cfg.scan_unroll())
    elif topo == "moe_il":
        def group(x_aux, gp):
            x, aux = x_aux
            dp, mp = gp
            x, a = _scan_apply(dp, x, cfg, "dense", positions, remat)
            x, a2 = B.block_apply(mp, x, cfg, "moe", positions)
            return (x, aux + a + a2), None

        gp_all = (params["blocks"], params["moe_blocks"])
        if act > 0:
            (x, aux), _ = jax.lax.scan(group, (x, aux),
                                       _slice_stack(gp_all, 0, act),
                                       unroll=scan_cfg.scan_unroll())
            x, aux = jax.lax.stop_gradient(x), jax.lax.stop_gradient(aux)
        if sub > act:
            (x, aux), _ = jax.lax.scan(group, (x, aux),
                                       _slice_stack(gp_all, act, sub),
                                       unroll=scan_cfg.scan_unroll())
    elif topo == "xlstm":
        def group(x_aux, gp):
            x, aux = x_aux
            mp, sp = gp
            x, a = _scan_apply(mp, x, cfg, "mlstm", positions, remat)
            x, a2 = B.block_apply(sp, x, cfg, "slstm", positions)
            return (x, aux + a + a2), None

        gp_all = (params["mlstm"], params["slstm"])
        if act > 0:
            (x, aux), _ = jax.lax.scan(group, (x, aux),
                                       _slice_stack(gp_all, 0, act),
                                       unroll=scan_cfg.scan_unroll())
            x, aux = jax.lax.stop_gradient(x), jax.lax.stop_gradient(aux)
        if sub > act:
            (x, aux), _ = jax.lax.scan(group, (x, aux),
                                       _slice_stack(gp_all, act, sub),
                                       unroll=scan_cfg.scan_unroll())
    x = B.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    return x, aux


# ---------------------------------------------------------------------------
# loss (chunked over sequence so (B,S,V) logits are never fully live)
# ---------------------------------------------------------------------------
def xent_loss(params, hidden, labels, cfg, mask=None):
    """hidden: (B,S,d); labels: (B,S) int32; mask: (B,S) {0,1}."""
    Bsz, S, d = hidden.shape
    W = _head_matrix(params, cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    if mask is None:
        mask = jnp.ones((Bsz, S), jnp.float32)
    c = LOSS_CHUNK if S % LOSS_CHUNK == 0 else S
    nc = S // c
    h = hidden.reshape(Bsz, nc, c, d).transpose(1, 0, 2, 3)
    y = labels.reshape(Bsz, nc, c).transpose(1, 0, 2)
    mk = mask.reshape(Bsz, nc, c).transpose(1, 0, 2)

    def body(acc, inp):
        hc, yc, mc = inp
        logits = (hc.astype(cdt) @ W.astype(cdt)).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        if XENT_GOLD_MODE == "wgather":
            # gather the label columns of W (one small-table gather) and
            # dot with the hidden state: no (B,c,V) gather, no V-sized
            # elementwise mask
            w_cols = jnp.take(W.T, yc, axis=0).astype(jnp.float32)
            gold = jnp.sum(hc.astype(jnp.float32) * w_cols, axis=-1)
        elif XENT_GOLD_MODE == "mask":
            # no gather over the (tensor-parallel-sharded) vocab dim:
            # elementwise select + reduce partitions cleanly (psum of (B,c))
            vocab_iota = jax.lax.broadcasted_iota(
                jnp.int32, logits.shape, logits.ndim - 1)
            gold = jnp.sum(
                jnp.where(vocab_iota == yc[..., None], logits, 0.0), axis=-1)
        else:
            gold = jnp.take_along_axis(logits, yc[..., None],
                                       axis=-1)[..., 0]
        loss = jnp.sum((logz - gold) * mc)
        return (acc[0] + loss, acc[1] + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (h, y, mk), unroll=scan_cfg.scan_unroll())
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, batch, cfg, *, sub_layers=None, active_from: int = 0,
            remat: bool = False):
    """batch: {"tokens": (B,S), "labels": (B,S), opt "frontend", opt "mask"}."""
    x = embed(params, batch["tokens"], cfg, batch.get("frontend"))
    hidden, aux = forward_hidden(params, x, cfg, sub_layers=sub_layers,
                                 active_from=active_from, remat=remat)
    P = 0 if batch.get("frontend") is None else batch["frontend"].shape[1]
    if P:
        hidden = hidden[:, P:]
    loss = xent_loss(params, hidden, batch["labels"], cfg, batch.get("mask"))
    return loss + aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------
def init_caches(cfg, batch: int, seq_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    topo = topology(cfg)

    def stack(n, kind, extra=()):
        one = B.block_cache_init(cfg, kind, batch, seq_len, dtype)
        reps = (n,) + extra
        # broadcast (not zeros!): recurrent states have non-zero inits
        # (mLSTM stabilizer m = -inf, sLSTM normalizer n = 1)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, reps + a.shape) + jnp.zeros(
                reps + a.shape, a.dtype), one)

    if topo == "uniform":
        kind = uniform_kind(cfg)
        c = stack(cfg.num_layers, kind)
        # attention caches need pos = -1 fill
        return _fix_pos(c, cfg)
    if topo == "zamba":
        g = cfg.num_layers // cfg.attn_every
        return _fix_pos({
            "mamba": stack(g, "mamba", (cfg.attn_every,)),
            "attn": stack(g, "attn_only"),
        }, cfg)
    if topo == "xlstm":
        per = cfg.xlstm.slstm_every or cfg.num_layers
        g = cfg.num_layers // per
        return {"mlstm": stack(g, "mlstm", (per - 1,)),
                "slstm": stack(g, "slstm")}
    if topo == "moe_il":
        k = cfg.moe.moe_every
        g = cfg.num_layers // k
        return _fix_pos({"dense": stack(g, "dense", (k - 1,)),
                         "moe": stack(g, "moe")}, cfg)
    raise ValueError(topo)


def _fix_pos(tree, cfg):
    """Attention cache 'pos' leaves start at -1 (empty-slot sentinel)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, a: jnp.full(a.shape, -1, a.dtype)
        if (getattr(p[-1], "key", None) == "pos") else a, tree)


def decode_step(params, caches, token, pos, cfg):
    """token: (B, 1) int32; pos: scalar int32. Returns (logits (B,1,V), caches)."""
    x = embed(params, token, cfg)
    topo = topology(cfg)

    if topo == "uniform":
        kind = uniform_kind(cfg)

        def body(x, xs):
            p, c = xs
            x, c2 = B.block_decode(p, x, c, pos, cfg, kind)
            return x, c2

        x, new_c = jax.lax.scan(body, x, (params["blocks"], caches),
                                unroll=scan_cfg.scan_unroll())
    elif topo == "zamba":
        def group(x, xs):
            gp, (mst, ac) = xs

            def inner(x, ys):
                p, st = ys
                x, st2 = B.block_decode(p, x, st, pos, cfg, "mamba")
                return x, st2

            x, mst2 = jax.lax.scan(inner, x, (gp, mst),
                                   unroll=scan_cfg.scan_unroll())
            x, ac2 = B.block_decode(params["shared_attn"], x, ac, pos, cfg,
                                    "attn_only")
            return x, (mst2, ac2)

        x, (m2, a2) = jax.lax.scan(
            group, x, (params["blocks"], (caches["mamba"], caches["attn"])),
            unroll=scan_cfg.scan_unroll())
        new_c = {"mamba": m2, "attn": a2}
    elif topo == "moe_il":
        def group(x, xs):
            (dp, mp), (dst, mst) = xs

            def inner(x, ys):
                p, st = ys
                x, st2 = B.block_decode(p, x, st, pos, cfg, "dense")
                return x, st2

            x, dst2 = jax.lax.scan(inner, x, (dp, dst),
                                   unroll=scan_cfg.scan_unroll())
            x, mst2 = B.block_decode(mp, x, mst, pos, cfg, "moe")
            return x, (dst2, mst2)

        x, (d2, m2) = jax.lax.scan(
            group, x, ((params["blocks"], params["moe_blocks"]),
                       (caches["dense"], caches["moe"])),
            unroll=scan_cfg.scan_unroll())
        new_c = {"dense": d2, "moe": m2}
    elif topo == "xlstm":
        def group(x, xs):
            (mp, sp), (mst, sst) = xs

            def inner(x, ys):
                p, st = ys
                x, st2 = B.block_decode(p, x, st, pos, cfg, "mlstm")
                return x, st2

            x, mst2 = jax.lax.scan(inner, x, (mp, mst),
                                   unroll=scan_cfg.scan_unroll())
            x, sst2 = B.block_decode(sp, x, sst, pos, cfg, "slstm")
            return x, (mst2, sst2)

        x, (m2, s2) = jax.lax.scan(
            group, x, ((params["mlstm"], params["slstm"]),
                       (caches["mlstm"], caches["slstm"])),
            unroll=scan_cfg.scan_unroll())
        new_c = {"mlstm": m2, "slstm": s2}
    else:
        raise ValueError(topo)

    x = B.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    cdt = jnp.dtype(cfg.compute_dtype)
    logits = (x.astype(cdt) @ _head_matrix(params, cfg).astype(cdt))
    return logits.astype(jnp.float32), new_c


def prefill(params, tokens, cfg, frontend=None):
    """Run the full prompt, return (last-token logits, hidden).

    The dry-run prefill step lowers this forward pass; decode benchmarks use
    ``init_caches`` + ``decode_step``. (Cache hand-off from prefill is
    exercised at test scale via per-block ``return_state`` paths.)
    """
    x = embed(params, tokens, cfg, frontend)
    hidden, _ = forward_hidden(params, x, cfg)
    last = hidden[:, -1:]
    cdt = jnp.dtype(cfg.compute_dtype)
    logits = (last.astype(cdt) @ _head_matrix(params, cfg).astype(cdt))
    return logits.astype(jnp.float32), hidden
