"""Vision Transformer backbone (ViT-Tiny) — the paper's encoder F.

Matches the paper's setup: 32x32x3 inputs, patch size 4 (trained patch
projection, per MoCo v3 deviation noted in the paper), learned positional
embeddings, CLS token, 12 blocks. Supports the layer-wise stage interface
(``sub_layers``, ``active_from``) used by FedMoCo-LW / LW-FedSSL /
Prog-FedSSL.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.layers.init import dense_init, embed_init
from repro.models.lm import _slice_stack, _stacked_init
from repro.models import scan_cfg


def num_patches(image_size: int, patch_size: int) -> int:
    return (image_size // patch_size) ** 2


def init_vit(key, cfg, image_size: int = 32, patch_size: int = 4):
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    n = num_patches(image_size, patch_size)
    return {
        "patch": dense_init(ks[0], (patch_size * patch_size * 3, cfg.d_model), dt),
        "pos": embed_init(ks[1], (n + 1, cfg.d_model), dt),
        "cls": embed_init(ks[2], (1, 1, cfg.d_model), dt),
        "blocks": _stacked_init(ks[3], cfg, "enc", cfg.num_layers),
        "final_ln": B.rmsnorm_init(cfg.d_model, dt),
    }


def patchify(images, patch_size: int):
    """images: (B, H, W, 3) -> (B, n_patches, P*P*3)."""
    Bsz, H, W, C = images.shape
    ph, pw = H // patch_size, W // patch_size
    x = images.reshape(Bsz, ph, patch_size, pw, patch_size, C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(Bsz, ph * pw, patch_size * patch_size * C)


def vit_forward(params, images, cfg, *, patch_size: int = 4,
                sub_layers=None, active_from: int = 0, remat: bool = False,
                layer_gates=None):
    """Returns CLS representation (B, d_model).

    layer_gates: optional (num_layers,) float gates multiplying each block's
    residual delta (depth dropout for FLL+DD; 1.0 = keep, 0.0 = skip).
    """
    x = patchify(images, patch_size).astype(jnp.dtype(cfg.param_dtype))
    x = x @ params["patch"]
    Bsz = x.shape[0]
    cls = jnp.broadcast_to(params["cls"], (Bsz, 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1) + params["pos"][None]

    sub = cfg.num_layers if sub_layers is None else sub_layers
    act = max(0, min(active_from, sub))

    def body(carry, pg):
        x, _ = carry
        p, g = pg
        fn = functools.partial(B.block_apply, cfg=cfg, kind="enc")
        if remat:
            fn = jax.checkpoint(fn)
        x2, a = fn(p, x)
        x = x + g.astype(x.dtype) * (x2 - x)
        return (x, a), None

    gates = (jnp.ones((cfg.num_layers,), jnp.float32)
             if layer_gates is None else layer_gates)
    if act > 0:
        (x, _), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                 (_slice_stack(params["blocks"], 0, act),
                                  gates[0:act]),
                                 unroll=scan_cfg.scan_unroll())
        x = jax.lax.stop_gradient(x)
    if sub > act:
        (x, _), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                 (_slice_stack(params["blocks"], act, sub),
                                  gates[act:sub]),
                                 unroll=scan_cfg.scan_unroll())
    x = B.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    return x[:, 0]
