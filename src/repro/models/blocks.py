"""Block-level composition: one residual block per kind.

Kinds: "dense" (GQA attn + MLP), "moe" (GQA attn + MoE FFN),
"mla_moe" (latent attention + MoE), "mamba" (Mamba2), "mlstm"/"slstm" (xLSTM),
"attn_only" (Zamba2 shared attention block: attn + MLP on the residual
stream), "enc" (bidirectional attn + MLP), "cross" (decoder block with
self + cross attention).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import attention as attn
from repro.models.layers import mamba2, mla, moe, xlstm
from repro.models.layers.mlp import mlp_apply, mlp_init
from repro.models.layers.norms import rmsnorm, rmsnorm_init


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def block_init(key, cfg, kind: str):
    dt = _dt(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind == "mamba":
        return {"ln": rmsnorm_init(d, dt), "mamba": mamba2.mamba2_init(ks[0], cfg, dt)}
    if kind == "mlstm":
        return {"ln": rmsnorm_init(d, dt), "mlstm": xlstm.mlstm_init(ks[0], cfg, dt)}
    if kind == "slstm":
        return {"ln": rmsnorm_init(d, dt), "slstm": xlstm.slstm_init(ks[0], cfg, dt)}
    if kind in ("dense", "attn_only", "enc"):
        a = attn.attn_init(ks[0], cfg, dt)
        return {"ln1": rmsnorm_init(d, dt), "attn": a,
                "ln2": rmsnorm_init(d, dt),
                "mlp": mlp_init(ks[1], d, cfg.d_ff, cfg.act, dt)}
    if kind == "moe":
        a = attn.attn_init(ks[0], cfg, dt)
        return {"ln1": rmsnorm_init(d, dt), "attn": a,
                "ln2": rmsnorm_init(d, dt), "moe": moe.moe_init(ks[1], cfg, dt)}
    if kind == "mla_moe":
        a = mla.mla_init(ks[0], cfg, dt)
        return {"ln1": rmsnorm_init(d, dt), "attn": a,
                "ln2": rmsnorm_init(d, dt), "moe": moe.moe_init(ks[1], cfg, dt)}
    if kind == "cross":
        return {"ln1": rmsnorm_init(d, dt), "attn": attn.attn_init(ks[0], cfg, dt),
                "ln_x": rmsnorm_init(d, dt), "xattn": attn.attn_init(ks[1], cfg, dt),
                "ln2": rmsnorm_init(d, dt),
                "mlp": mlp_init(ks[2], d, cfg.d_ff, cfg.act, dt)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# MoE dispatch: grouped (per-sample capacity) for S>1, dense for decode
# ---------------------------------------------------------------------------
def _moe_ffn(params, x, cfg):
    """x: (B, S, d) -> (out, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    logits = (xc @ params["router"].astype(cdt)).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, m.experts_per_token)            # (B,S,k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    frac = jnp.mean(jax.nn.one_hot(topi, m.num_experts, dtype=jnp.float32),
                    axis=(0, 1, 2))
    aux = m.num_experts * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    wg = params["w_gate"].astype(cdt)
    wu = params["w_up"].astype(cdt)
    wd = params["w_down"].astype(cdt)
    if S == 1:
        # decode: small-batch serving is weight-memory-bound; compute all
        # experts densely and combine (every expert's weights stream from HBM
        # regardless — see DESIGN.md).
        w_tok = jnp.sum(jax.nn.one_hot(topi, m.num_experts, dtype=jnp.float32)
                        * topv[..., None], axis=2)                    # (B,1,E)
        h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", xc, wg)) * \
            jnp.einsum("bsd,edf->bsef", xc, wu)
        y = jnp.einsum("bsef,efd->bsed", h, wd)
        out = jnp.einsum("bsed,bse->bsd", y, w_tok.astype(cdt))
    else:
        # per-sample (GShard group = batch row) capacity dispatch
        cap = max(1, min(S, int(S * m.experts_per_token * m.capacity_factor
                                / m.num_experts)))
        w_se = jnp.sum(jax.nn.one_hot(topi, m.num_experts, dtype=jnp.float32)
                       * topv[..., None], axis=2)                     # (B,S,E)
        w_es = w_se.transpose(0, 2, 1)                                # (B,E,S)
        selv, seli = jax.lax.top_k(w_es, cap)                         # (B,E,C)
        bidx = jnp.arange(B)[:, None, None]
        xin = xc[bidx, seli]                                          # (B,E,C,d)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, wg)) * \
            jnp.einsum("becd,edf->becf", xin, wu)
        y = jnp.einsum("becf,efd->becd", h, wd)
        y = y * selv[..., None].astype(cdt)
        out = jnp.zeros((B, S, d), cdt).at[bidx, seli].add(y)
    if m.num_shared_experts:
        out = out + moe.shared_expert_ffn(params, x, cfg).astype(cdt)
    return out.astype(x.dtype), aux * m.router_aux_loss


# ---------------------------------------------------------------------------
# full-sequence apply (train / prefill).  Returns (x, aux_loss)
# ---------------------------------------------------------------------------
def block_apply(params, x, cfg, kind: str, positions=None, memory=None):
    aux = jnp.float32(0.0)
    if kind == "mamba":
        return x + mamba2.mamba2_apply(params["mamba"],
                                       rmsnorm(params["ln"], x, cfg.norm_eps),
                                       cfg), aux
    if kind == "mlstm":
        return x + xlstm.mlstm_apply(params["mlstm"],
                                     rmsnorm(params["ln"], x, cfg.norm_eps),
                                     cfg), aux
    if kind == "slstm":
        return x + xlstm.slstm_apply(params["slstm"],
                                     rmsnorm(params["ln"], x, cfg.norm_eps),
                                     cfg), aux
    if kind == "enc":
        cfg = dataclasses.replace(cfg, causal=False)
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        x = x + attn.attn_apply(params["attn"], h, cfg, positions)
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        return x + mlp_apply(params["mlp"], h, cfg.act,
                             jnp.dtype(cfg.compute_dtype)), aux
    if kind in ("dense", "attn_only"):
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        x = x + attn.attn_apply(params["attn"], h, cfg, positions)
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        return x + mlp_apply(params["mlp"], h, cfg.act,
                             jnp.dtype(cfg.compute_dtype)), aux
    if kind == "moe":
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        x = x + attn.attn_apply(params["attn"], h, cfg, positions)
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        y, aux = _moe_ffn(params["moe"], h, cfg)
        return x + y, aux
    if kind == "mla_moe":
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        x = x + mla.mla_apply(params["attn"], h, cfg, positions)
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        y, aux = _moe_ffn(params["moe"], h, cfg)
        return x + y, aux
    if kind == "cross":
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        x = x + attn.attn_apply(params["attn"], h, cfg, positions)
        h = rmsnorm(params["ln_x"], x, cfg.norm_eps)
        x = x + attn.cross_attn_apply(params["xattn"], h, memory, cfg)
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        return x + mlp_apply(params["mlp"], h, cfg.act,
                             jnp.dtype(cfg.compute_dtype)), aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# caches / states
# ---------------------------------------------------------------------------
def block_cache_init(cfg, kind: str, batch: int, seq_len: int, dtype):
    if kind == "mamba":
        return mamba2.init_state(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm.mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return xlstm.slstm_init_state(cfg, batch)
    if kind == "mla_moe":
        return mla.init_cache(cfg, batch, seq_len, dtype)
    return attn.init_cache(cfg, batch, seq_len, dtype)     # dense/moe/attn_only/cross


def block_decode(params, x, cache, cur_pos, cfg, kind: str, memory=None):
    """Single-token decode step. x: (B, 1, d). Returns (x, new_cache)."""
    if kind == "mamba":
        y, st = mamba2.mamba2_decode(params["mamba"],
                                     rmsnorm(params["ln"], x, cfg.norm_eps),
                                     cache, cfg)
        return x + y, st
    if kind == "mlstm":
        y, st = xlstm.mlstm_decode(params["mlstm"],
                                   rmsnorm(params["ln"], x, cfg.norm_eps),
                                   cache, cfg)
        return x + y, st
    if kind == "slstm":
        y, st = xlstm.slstm_decode(params["slstm"],
                                   rmsnorm(params["ln"], x, cfg.norm_eps),
                                   cache, cfg)
        return x + y, st
    if kind in ("dense", "attn_only", "moe"):
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        y, cache = attn.attn_decode(params["attn"], h, cache, cur_pos, cfg)
        x = x + y
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        if kind == "moe":
            y2, _ = _moe_ffn(params["moe"], h, cfg)
        else:
            y2 = mlp_apply(params["mlp"], h, cfg.act, jnp.dtype(cfg.compute_dtype))
        return x + y2, cache
    if kind == "mla_moe":
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        y, cache = mla.mla_decode(params["attn"], h, cache, cur_pos, cfg)
        x = x + y
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        y2, _ = _moe_ffn(params["moe"], h, cfg)
        return x + y2, cache
    if kind == "cross":
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        y, cache = attn.attn_decode(params["attn"], h, cache, cur_pos, cfg)
        x = x + y
        h = rmsnorm(params["ln_x"], x, cfg.norm_eps)
        x = x + attn.cross_attn_apply(params["xattn"], h, memory, cfg)
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        return x + mlp_apply(params["mlp"], h, cfg.act,
                             jnp.dtype(cfg.compute_dtype)), cache
    raise ValueError(kind)
