"""ShapeDtypeStruct stand-ins for every lowered input (no allocation).

``input_specs(arch_id, shape_name, mesh, mode)`` returns (step_args, cfg):
abstract arrays carrying NamedShardings, ready for
``jax.jit(step).lower(*step_args)``. Parameters and optimizer state are
shaped with ``jax.eval_shape`` over the real initializers, so the dry-run
exercises exactly the structures the launchers train/serve.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, load_arch, load_train
from repro.launch import steps as steps_mod
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.optim import make_optimizer
from repro.sharding import rules


def _with_shardings(shapes_tree, specs_tree, mesh):
    return jax.tree.map(
        lambda s, spec: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec)),
        shapes_tree, specs_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def param_shapes(cfg):
    if steps_mod.is_encdec(cfg):
        return jax.eval_shape(
            lambda: encdec_mod.init_encdec(jax.random.PRNGKey(0), cfg))
    return jax.eval_shape(lambda: lm_mod.init_lm(jax.random.PRNGKey(0), cfg))


def sharded_params(cfg, mesh):
    shapes = param_shapes(cfg)
    specs = rules.param_pspecs(shapes, mesh)
    return _with_shardings(shapes, specs, mesh), specs


def batch_shapes(cfg, shape, *, for_train: bool):
    """Token/label/frontend abstract batch for one global step."""
    B, S = shape.global_batch, shape.seq_len
    fe = cfg.frontend_embed_len
    if steps_mod.is_encdec(cfg):
        d = {"frontend": jax.ShapeDtypeStruct((B, fe, cfg.d_model),
                                              jnp.float32),
             "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if for_train:
            d["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return d
    tok_len = S - fe if fe else S
    d = {"tokens": jax.ShapeDtypeStruct((B, tok_len), jnp.int32)}
    if fe:
        d["frontend"] = jax.ShapeDtypeStruct((B, fe, cfg.d_model),
                                             jnp.float32)
    if for_train:
        d["labels"] = jax.ShapeDtypeStruct((B, tok_len), jnp.int32)
    return d


def input_specs(arch_id: str, shape_name: str, mesh, *,
                mode: str = None, cfg_override=None):
    """Returns (step_fn, step_args, cfg, train_cfg)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = steps_mod.cfg_for_shape(cfg_override or load_arch(arch_id),
                                  shape_name)
    train_cfg = load_train(arch_id)
    mode = mode or ("train" if shape.kind == "train" else shape.kind)

    if mode in ("train", "train_lw"):
        step, opt = steps_mod.make_train_step(cfg, train_cfg, mode=mode)
        p_sds, p_specs = sharded_params(cfg, mesh)
        opt_shapes = jax.eval_shape(opt.init, p_sds)
        opt_specs = rules.opt_state_specs(opt_shapes, p_specs,
                                          train_cfg.optimizer, mesh)
        opt_sds = _with_shardings(opt_shapes, opt_specs, mesh)
        b_shapes = batch_shapes(cfg, shape, for_train=True)
        b_sds = _with_shardings(b_shapes, rules.batch_specs(b_shapes, mesh),
                                mesh)
        args = [p_sds, opt_sds, b_sds]
        if mode == "train_lw":
            args.append(p_sds)          # broadcast global model (alignment)
        return step, tuple(args), cfg, train_cfg

    if mode == "prefill":
        step = steps_mod.make_prefill_step(cfg)
        p_sds, _ = sharded_params(cfg, mesh)
        b_shapes = batch_shapes(cfg, shape, for_train=False)
        b_sds = _with_shardings(b_shapes, rules.batch_specs(b_shapes, mesh),
                                mesh)
        if steps_mod.is_encdec(cfg):
            return step, (p_sds, b_sds["frontend"], b_sds["tokens"]), \
                cfg, train_cfg
        return step, (p_sds, b_sds), cfg, train_cfg

    if mode == "decode":
        step = steps_mod.make_decode_step(cfg)
        p_sds, _ = sharded_params(cfg, mesh)
        B, S = shape.global_batch, shape.seq_len
        cdt = jnp.dtype(cfg.compute_dtype)
        if steps_mod.is_encdec(cfg):
            cache_shapes = jax.eval_shape(
                lambda: encdec_mod.init_dec_caches(cfg, B, S, cdt))
        else:
            cache_shapes = jax.eval_shape(
                lambda: lm_mod.init_caches(cfg, B, S, cdt))
        c_specs = rules.cache_pspecs(cache_shapes, mesh, B)
        c_sds = _with_shardings(cache_shapes, c_specs, mesh)
        tok_spec = rules.batch_specs(
            {"t": jax.ShapeDtypeStruct((B, 1), jnp.int32)}, mesh)["t"]
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                   sharding=NamedSharding(mesh, tok_spec))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        if steps_mod.is_encdec(cfg):
            fe = cfg.frontend_embed_len
            mem_spec = rules.batch_specs(
                {"m": jax.ShapeDtypeStruct((B, fe, cfg.d_model),
                                           jnp.float32)}, mesh)["m"]
            mem = jax.ShapeDtypeStruct(
                (B, fe, cfg.d_model), jnp.float32,
                sharding=NamedSharding(mesh, mem_spec))
            return step, (p_sds, c_sds, tok, pos, mem), cfg, train_cfg
        return step, (p_sds, c_sds, tok, pos), cfg, train_cfg

    raise ValueError(mode)
