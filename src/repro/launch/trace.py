"""Trace analysis CLI — paper tables as views over telemetry.

Reads the JSONL traces the observability layer writes (``--trace`` on
``repro.launch.train``, ``run_fedssl(obs=...)``) and regenerates, from the
spans alone:

  round-time breakdown   wall-clock per phase (download / local_train /
                         calibrate, engine and transport child spans)
                         aggregated across rounds, per trace.
  comm table             per-schedule analytic + measured wire bytes
                         summed over the ``round`` spans, with ratios
                         against the e2e trace when one is among the
                         inputs — the paper's Table 1/3 communication
                         columns (0.08 / 0.31 / 0.54 vs FedMoCo) read
                         straight off a trace.

Because byte telemetry depends only on (parameter shapes x round plan),
the CLI can also *emit* a paper-scale comm trace without training
(``--emit-comm``): it walks the full 180-round schedule over the
``eval_shape``-abstract ViT-T + MoCo tree, routes every round's payload
specs through the real ``Transport`` byte accounting, and records the
same ``round`` spans the driver would — seconds instead of GPU-days, and
byte-for-byte equal to ``comm.round_comm_bytes`` (fp32). The paper table
is then just this CLI analyzing its own traces:

  python -m repro.launch.trace --emit-comm --out-dir results/
  python -m repro.launch.trace results/comm_trace_*.jsonl

See docs/observability.md.
"""
from __future__ import annotations

import argparse
import pathlib
from typing import Any, Dict, List, Sequence, Tuple

from repro.obs import read_jsonl, write_jsonl
from repro.obs.trace import Tracer

COMM_ATTRS = ("download_bytes", "upload_bytes", "wire_download_bytes",
              "wire_upload_bytes")


# ---------------------------------------------------------------------------
# analysis: traces -> tables
# ---------------------------------------------------------------------------
def run_args(events: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """Attributes of the trace's ``run`` span (schedule, engine, codec)."""
    for e in events:
        if e["name"] == "run":
            return dict(e["args"])
    return {}


def round_spans(events: Sequence[Dict[str, Any]]) -> List[Dict[str, Any]]:
    return [e for e in events
            if e["name"] == "round" and e["ph"] == "X"]


def comm_totals(events: Sequence[Dict[str, Any]]) -> Dict[str, int]:
    """Sum the per-round byte attributes over the trace's round spans."""
    totals = {a: 0 for a in COMM_ATTRS}
    for e in round_spans(events):
        for a in COMM_ATTRS:
            totals[a] += int(e["args"].get(a, 0))
    totals["comm_bytes"] = (totals["download_bytes"]
                            + totals["upload_bytes"])
    totals["wire_bytes"] = (totals["wire_download_bytes"]
                            + totals["wire_upload_bytes"])
    totals["rounds"] = len(round_spans(events))
    return totals


def comm_table(traces: Sequence[Tuple[Dict, List[Dict]]]
               ) -> List[Dict[str, Any]]:
    """One row per trace: schedule, byte totals, and — when an ``e2e``
    trace is among the inputs — the download/upload/total ratios against
    it (the paper's comm multiplier columns)."""
    rows = []
    for header, events in traces:
        info = run_args(events)
        row = {"schedule": info.get("schedule",
                                    header.get("schedule", "?")),
               "codec": info.get("codec", "?")}
        row.update(comm_totals(events))
        rows.append(row)
    base = next((r for r in rows if r["schedule"] == "e2e"), None)
    for r in rows:
        if base is not None and base["comm_bytes"] > 0:
            r["download_ratio"] = r["download_bytes"] / max(
                1, base["download_bytes"])
            r["upload_ratio"] = r["upload_bytes"] / max(
                1, base["upload_bytes"])
            r["comm_ratio"] = r["comm_bytes"] / base["comm_bytes"]
    return rows


def round_breakdown(events: Sequence[Dict[str, Any]]
                    ) -> Dict[str, Dict[str, float]]:
    """Aggregate span durations by name: {name: {count, total_s, mean_s}}
    for every completed wall-clock span (virtual sim tracks excluded)."""
    out: Dict[str, Dict[str, float]] = {}
    for e in events:
        if e["ph"] != "X" or e["cat"] == "sim":
            continue
        d = out.setdefault(e["name"], {"count": 0, "total_s": 0.0})
        d["count"] += 1
        d["total_s"] += e["dur"] / 1e6
    for d in out.values():
        d["mean_s"] = d["total_s"] / d["count"]
    return out


def print_breakdown(path, events):
    info = run_args(events)
    label = " ".join(f"{k}={info[k]}" for k in
                     ("schedule", "engine", "codec") if k in info)
    print(f"\n-- {path}: {label}")
    br = round_breakdown(events)
    order = sorted(br, key=lambda n: -br[n]["total_s"])
    print(f"   {'span':24s} {'count':>6s} {'total':>10s} {'mean':>10s}")
    for name in order:
        d = br[name]
        print(f"   {name:24s} {d['count']:6d} {d['total_s']:9.3f}s "
              f"{d['mean_s'] * 1e3:8.2f}ms")


def print_comm_table(rows):
    print(f"\n== comm totals (from round spans) ==")
    hdr = (f"{'schedule':12s} {'rounds':>6s} {'down(MB)':>10s} "
           f"{'up(MB)':>10s} {'wire(MB)':>10s}")
    has_ratio = any("comm_ratio" in r for r in rows)
    if has_ratio:
        hdr += f" {'down x':>8s} {'up x':>8s} {'comm x':>8s}"
    print(hdr)
    for r in rows:
        line = (f"{r['schedule']:12s} {r['rounds']:6d} "
                f"{r['download_bytes'] / 1e6:10.1f} "
                f"{r['upload_bytes'] / 1e6:10.1f} "
                f"{r['wire_bytes'] / 1e6:10.1f}")
        if "comm_ratio" in r:
            line += (f" {r['download_ratio']:8.2f} {r['upload_ratio']:8.2f}"
                     f" {r['comm_ratio']:8.2f}")
        print(line)
    if has_ratio:
        print("(ratios vs the e2e trace — paper Table 3 comm column: "
              "layerwise 0.08, lw_fedssl 0.31, progressive 0.54)")


# ---------------------------------------------------------------------------
# paper table: measured vs analytic vs published resource reductions
# ---------------------------------------------------------------------------
def fullscale_comm(schedule: str, *, arch: str = "vit-tiny",
                   rounds: int = 180, include_heads: bool = False) -> int:
    """Total comm bytes of ``schedule`` at paper scale — the same
    abstract-tree walk as ``emit_comm_trace`` without writing a trace.
    Ratios against e2e reproduce the paper's comm multipliers exactly
    (0.08 / 0.31 / 0.54)."""
    from repro.configs.base import FLConfig, SSLConfig, load_arch
    from repro.core import schedule as sched
    from repro.federated import comm
    from repro.roofline.client_costs import build_ssl_param_tree

    cfg = load_arch(arch)
    online = build_ssl_param_tree(cfg, SSLConfig())["online"]
    fl = FLConfig(rounds=rounds, schedule=schedule,
                  include_heads=include_heads)
    total = 0
    for plan in sched.build_schedule(fl, cfg.num_layers):
        cb = comm.round_comm_bytes(online, plan,
                                   include_heads=include_heads)
        total += cb["download"] + cb["upload"]
    return total


def paper_table(*, engines=("sequential", "vmap"), arch: str = "vit-tiny",
                comm_rounds: int = 180, measure_rounds: int = 20,
                compile_memory: bool = True, log=None) -> dict:
    """Build the measured-resources paper table document.

    Three sources per schedule: *measured* FLOPs/peak-memory from the
    engines' compiled XLA round programs at the reduced measurement
    config (``repro.obs.resources.measure_schedule``), *analytic*
    predictions evaluated on the same config (and, for the reduction
    multipliers, at full scale via ``client_costs.schedule_costs``), and
    the paper's published Table 3 multipliers. Comm is measured at full
    scale through the abstract transport walk — the one column where
    measurement and paper operate at identical scale, which is why its
    multipliers must (and do) match the paper exactly."""
    from repro.core import schedule as sched
    from repro.obs import resources as res_mod
    from repro.roofline import client_costs as cc

    comm_bytes = {s: fullscale_comm(s, arch=arch, rounds=comm_rounds)
                  for s in sched.SCHEDULES}
    analytic_full = {s: cc.schedule_costs(s, rounds=comm_rounds)
                     for s in sched.SCHEDULES}
    rows = []
    for engine in engines:
        for s in sched.SCHEDULES:
            m = res_mod.measure_schedule(
                s, engine, rounds=measure_rounds,
                compile_memory=compile_memory, log=log)
            m["comm_bytes"] = comm_bytes[s]
            m["comm_ratio"] = comm_bytes[s] / comm_bytes["e2e"]
            m["analytic_flops_ratio"] = (
                analytic_full[s]["flops_total"]
                / analytic_full["e2e"]["flops_total"])
            m["analytic_memory_ratio"] = (
                analytic_full[s]["peak_memory"]
                / analytic_full["e2e"]["peak_memory"])
            rows.append(m)
        base = next(r for r in rows
                    if r["engine"] == engine and r["schedule"] == "e2e")
        for r in rows:
            if r["engine"] != engine:
                continue
            r["flops_ratio"] = r["flops_total"] / base["flops_total"]
            r["memory_ratio"] = (
                r["peak_memory"] / base["peak_memory"]
                if r["peak_memory"] and base["peak_memory"] else None)
    meas = rows[0]
    return {
        "version": 1,
        "arch": arch, "comm_rounds": comm_rounds,
        "measurement": {"num_layers": meas["num_layers"],
                        "batch_size": meas["batch_size"],
                        "rounds": meas["rounds"],
                        "local_epochs": meas["local_epochs"]},
        "tolerances": {"flops_rtol": res_mod.FLOPS_RTOL,
                       "memory_factor": res_mod.MEMORY_FACTOR},
        "paper_mult": {s: list(cc.PAPER_MULT[s]) for s in sched.SCHEDULES},
        "rows": rows,
    }


def print_paper_table(doc: dict):
    from repro.roofline.client_costs import PAPER_MULT, SCHEDULE_NAMES

    m = doc["measurement"]
    print(f"\n== measured resources vs analytic vs paper "
          f"(XLA cost/memory analysis) ==")
    print(f"measurement config: {m['num_layers']} layers, batch "
          f"{m['batch_size']}, {m['rounds']} rounds x "
          f"{m['local_epochs']} local epochs (reduced {doc['arch']}); "
          f"comm at full {doc['arch']} scale, {doc['comm_rounds']} rounds")
    hdr = (f"{'engine':10s} {'schedule':12s} {'GFLOPs':>9s} {'vs-an':>6s} "
           f"{'peak MiB':>9s} {'vs-an':>6s} "
           f"{'flops x':>8s} {'mem x':>6s} {'comm x':>7s} "
           f"{'paper (m/f/c)':>16s}")
    print(hdr)
    for r in doc["rows"]:
        pm = PAPER_MULT[r["schedule"]]
        fl_vs = r["flops_total"] / r["analytic_flops_total"]
        if r["peak_memory"]:
            mem = f"{r['peak_memory'] / 2**20:9.1f}"
            mem_vs = f"{r['peak_memory'] / r['program_peak_analytic']:6.2f}"
            mem_x = (f"{r['memory_ratio']:6.2f}"
                     if r.get("memory_ratio") else "     -")
        else:
            mem, mem_vs, mem_x = "        -", "     -", "     -"
        print(f"{r['engine']:10s} {r['schedule']:12s} "
              f"{r['flops_total'] / 1e9:9.2f} {fl_vs:6.2f} "
              f"{mem} {mem_vs} "
              f"{r['flops_ratio']:8.2f} {mem_x} {r['comm_ratio']:7.2f} "
              f"{pm[0]:.2f}/{pm[1]:.2f}/{pm[2]:.2f}")
    print("(vs-an: measured / analytic at the measurement config — "
          f"flops within {doc['tolerances']['flops_rtol']:.0%}, peak "
          f"within {doc['tolerances']['memory_factor']:.3g}x; "
          "x-columns: reduction vs this engine's e2e row; comm x is "
          "full-scale and matches the paper column exactly. Program "
          "memory is schedule-flat because both engines keep the full "
          "state + optimizer resident — the paper's idealized client "
          "footprint multipliers are the analytic table: "
          + ", ".join(f"{SCHEDULE_NAMES[s]} {PAPER_MULT[s][0]:.2f}"
                      for s in PAPER_MULT) + ")")


# ---------------------------------------------------------------------------
# emit: paper-scale comm traces without training
# ---------------------------------------------------------------------------
def emit_comm_trace(schedule: str, out, *, arch: str = "vit-tiny",
                    rounds: int = 180, codec: str = "fp32",
                    include_heads: bool = False) -> pathlib.Path:
    """Walk ``schedule`` over the abstract (eval_shape) model tree and
    write a trace whose ``round`` spans carry exactly the byte attributes
    a real traced run records — the comm accounting is the driver's own
    (``comm.round_comm_bytes`` + ``Transport`` wire sizes), only the
    training in between is skipped. ``include_heads=False`` matches the
    paper's encoder-only comm columns (``benchmarks.resources``).

    For delta codecs (topk) the recorded wire bytes are the steady-state
    sparse sizes; the dense re-sync round at stage transitions is a
    live-run behavior this dry walk does not model."""
    import jax

    from repro.configs.base import FLConfig, SSLConfig, load_arch
    from repro.core import schedule as sched
    from repro.core import ssl as ssl_mod
    from repro.federated import comm
    from repro.federated import transport as transport_mod

    cfg = load_arch(arch)
    ssl_cfg = SSLConfig()
    enc = ssl_mod.make_vit_encoder(cfg)
    state = jax.eval_shape(
        lambda k: ssl_mod.ssl_init(k, enc, ssl_cfg), jax.random.PRNGKey(0))
    online = state["online"]
    wire = transport_mod.Transport(codec, include_heads=include_heads)
    fl = FLConfig(rounds=rounds, schedule=schedule,
                  include_heads=include_heads)
    plans = sched.build_schedule(fl, enc.num_stages)
    tracer = Tracer()
    with tracer.span("run", cat="fl", mode="comm-dryrun",
                     schedule=schedule, arch=arch, codec=wire.codec.name,
                     rounds=rounds, include_heads=include_heads):
        for plan in plans:
            cb = comm.round_comm_bytes(online, plan,
                                       include_heads=include_heads)
            specs = wire.plan_specs(online, plan)
            with tracer.span("round", cat="fl", round=plan.round_idx,
                             stage=plan.stage,
                             download_bytes=cb["download"],
                             upload_bytes=cb["upload"],
                             wire_download_bytes=wire.wire_bytes(
                                 specs["download"]),
                             wire_upload_bytes=wire.wire_bytes(
                                 specs["upload"])):
                pass
    return write_jsonl(tracer, out, source="comm-dryrun")


def main(argv=None):
    from repro.core import schedule as sched

    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.trace",
        description="Analyze repro JSONL traces (round-time breakdown + "
                    "comm table), or emit paper-scale comm traces "
                    "without training (--emit-comm).")
    ap.add_argument("traces", nargs="*",
                    help="JSONL trace files to analyze")
    ap.add_argument("--emit-comm", action="store_true",
                    help="emit comm-dryrun traces instead of analyzing")
    ap.add_argument("--paper-table", action="store_true",
                    help="measure memory/GFLOPs from the compiled XLA "
                         "round programs (both engines x all five "
                         "schedules) and print them next to the analytic "
                         "roofline and the paper's published multipliers; "
                         "comm is the full-scale transport walk "
                         "(docs/observability.md, 'Measured resources')")
    ap.add_argument("--engines", default="sequential,vmap",
                    help="--paper-table: comma-separated round engines "
                         "to measure")
    ap.add_argument("--measure-rounds", type=int, default=20,
                    help="--paper-table: rounds in the measurement "
                         "schedule (flops totals scale with it; ratios "
                         "do not)")
    ap.add_argument("--skip-memory", action="store_true",
                    help="--paper-table: skip the per-schedule XLA "
                         "compile that measures peak memory (lowering "
                         "for flops is cheap; compiling is not)")
    ap.add_argument("--json", default="",
                    help="--paper-table: also write the table document "
                         "to this JSON path (the CI artifact)")
    ap.add_argument("--schedule", default=None, choices=sched.SCHEDULES,
                    help="emit only this schedule (default: all five)")
    ap.add_argument("--arch", default="vit-tiny")
    ap.add_argument("--rounds", type=int, default=180)
    ap.add_argument("--codec", default="fp32")
    ap.add_argument("--include-heads", action="store_true",
                    help="count the SSL heads in the payload (paper "
                         "tables are encoder-only)")
    ap.add_argument("--out-dir", default="results",
                    help="--emit-comm output directory "
                         "(comm_trace_<schedule>.jsonl)")
    args = ap.parse_args(argv)

    if args.paper_table:
        doc = paper_table(
            engines=tuple(e for e in args.engines.split(",") if e),
            arch=args.arch, comm_rounds=args.rounds,
            measure_rounds=args.measure_rounds,
            compile_memory=not args.skip_memory, log=print)
        print_paper_table(doc)
        if args.json:
            import json
            with open(args.json, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
                f.write("\n")
            print(f"wrote {args.json}")
        if not args.traces and not args.emit_comm:
            return

    if args.emit_comm:
        schedules = ((args.schedule,) if args.schedule
                     else sched.SCHEDULES)
        for s in schedules:
            out = pathlib.Path(args.out_dir) / f"comm_trace_{s}.jsonl"
            emit_comm_trace(s, out, arch=args.arch, rounds=args.rounds,
                            codec=args.codec,
                            include_heads=args.include_heads)
            print(f"wrote {out}")
        if not args.traces:
            args.traces = [str(pathlib.Path(args.out_dir)
                               / f"comm_trace_{s}.jsonl")
                           for s in schedules]

    if not args.traces:
        ap.error("nothing to do: pass trace files and/or --emit-comm")
    loaded = [(p, read_jsonl(p)) for p in args.traces]
    for p, (header, events) in loaded:
        print_breakdown(p, events)
    print_comm_table(comm_table([t for _, t in loaded]))


if __name__ == "__main__":
    main()
