"""Batched serving driver: prefill a batch of prompts, then decode tokens.

On CPU this runs a reduced variant of the requested architecture; on TPU
the same code path serves the full config with the dry-run's shardings
(decode caches are context-parallel, see repro.sharding.rules).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-2.7b \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import load_arch, reduced
from repro.launch.steps import is_encdec, make_decode_step
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod


def serve(arch: str, batch: int, prompt_len: int, gen: int, seed: int = 0,
          full: bool = False, greedy: bool = True, log=print):
    cfg = load_arch(arch) if full else reduced(load_arch(arch))
    key = jax.random.PRNGKey(seed)
    ki, kp = jax.random.split(key)
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
    max_len = prompt_len + gen
    if is_encdec(cfg):
        params = encdec_mod.init_encdec(ki, cfg)
        frames = jax.random.normal(kp, (batch, cfg.frontend_embed_len,
                                        cfg.d_model))
        memory = encdec_mod.encode(params, frames, cfg)
        caches = encdec_mod.init_dec_caches(cfg, batch, max_len)
        tok = jnp.zeros((batch, 1), jnp.int32)
        extra = (memory,)
    else:
        params = lm_mod.init_lm(ki, cfg)
        prompts = jax.random.randint(kp, (batch, prompt_len), 0,
                                     cfg.vocab_size)
        caches = lm_mod.init_caches(cfg, batch, max_len)
        # prefill by stepping the decoder over the prompt (cache-exact)
        tok = prompts[:, :1]
        for t in range(prompt_len):
            logits, caches = decode(params, caches, prompts[:, t:t + 1],
                                    jnp.int32(t))
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32) if greedy \
            else prompts[:, -1:]
        extra = ()
    out_tokens = []
    t0 = time.time()
    for t in range(gen):
        pos = jnp.int32((prompt_len if not is_encdec(cfg) else t))
        logits, caches = decode(params, caches, tok, pos + t
                                if not is_encdec(cfg) else pos, *extra)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out_tokens.append(jax.device_get(tok)[:, 0])
    dt = time.time() - t0
    tps = batch * gen / dt
    log(f"{arch}: generated {gen} tokens x {batch} seqs in {dt:.2f}s "
        f"({tps:.1f} tok/s on {jax.default_backend()})")
    return out_tokens, tps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true",
                    help="full config (TPU); default reduced for CPU")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve(args.arch, args.batch, args.prompt_len, args.gen, args.seed,
          full=args.full)


if __name__ == "__main__":
    main()
