"""Federated SSL training launcher.

Two modes:
  vit   — the paper's experiment: ViT backbone + MoCo v3 federated SSL on
          synthetic images (STL-10 stand-in), any of the five schedules.
  lm    — LM-family FedSSL: clients run next-token SSL + representation
          alignment on synthetic token shards (reduced arch on CPU).

On the production mesh the per-client local step is the pjit'd program the
dry-run lowers (repro.launch.steps); this launcher exercises the identical
round/stage logic at host scale so the whole FL system is runnable
end-to-end in this container.

Either mode runs on one of two round engines (``--engine``): ``sequential``
trains sampled clients one at a time (the numerical reference), ``vmap``
stacks them on a leading axis and executes each round — all clients' local
steps plus FedAvg — as a single jit'd program (``repro.federated.engine``).

Both modes route every download/upload through the wire transport
(``--codec``: fp32 | fp16 | bf16 | int8 | topk[:frac]), on either wire
engine (``--transport-kernels``: xla | pallas — the latter is the fused
pack/codec kernel path, docs/kernels.md); see docs/transport.md for
payload layout and codec semantics.

Privacy (both modes): ``--dp-clip / --dp-noise-multiplier / --dp-delta /
--dp-epsilon-budget`` enable client-level DP-FedAvg with RDP accounting,
``--secure-agg`` swaps FedAvg for pairwise-mask fixed-point secure
aggregation; see docs/privacy.md.

Example:
  PYTHONPATH=src python -m repro.launch.train --mode vit \
      --schedule lw_fedssl --rounds 12 --clients 4 --batch 64 \
      --engine vmap --codec int8
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (FLConfig, SSLConfig, TrainConfig, load_arch,
                                reduced)
from repro.core import schedule as sched
from repro.core import ssl as ssl_mod
from repro.data import iid_partition, dirichlet_partition, synthetic_images
from repro.data.synthetic import synthetic_tokens
from repro.federated import aggregate, comm
from repro.federated import fleet as fleet_mod
from repro.federated import simulation as sim_mod
from repro.federated import transport as transport_mod
from repro.federated.driver import run_fedssl
from repro.federated import eval as fl_eval
from repro.obs import (ConsoleRenderer, format_round_line, make_obs,
                       write_history_json)
from repro.optim import make_optimizer
from repro.optim.schedules import learning_rate, scaled_base_lr
from repro.privacy import PrivacyConfig, PrivacyEngine, make_privacy


def privacy_from_args(args):
    """PrivacyConfig from --dp-*/--secure-agg; None with everything off."""
    if (args.dp_clip == 0.0 and args.dp_noise_multiplier == 0.0
            and not args.secure_agg):
        return None
    return PrivacyConfig(
        clip=args.dp_clip, noise_multiplier=args.dp_noise_multiplier,
        delta=args.dp_delta, epsilon_budget=args.dp_epsilon_budget,
        secure_agg=args.secure_agg)


def obs_from_args(args, mode):
    """Observability bundle from --trace/--metrics/--profile-dir plus the
    health monitor (--health/--halt-on-unhealthy) and the measured
    per-stage cost attribution (--measure-resources)."""
    return make_obs(trace=args.trace, metrics=args.metrics,
                    profile_dir=args.profile_dir or None,
                    health=args.health,
                    halt_on_unhealthy=args.halt_on_unhealthy,
                    measure_resources=args.measure_resources,
                    mode=mode, schedule=args.schedule, engine=args.engine,
                    codec=args.codec, seed=args.seed)


def export_obs(obs, args, hist=None):
    """Write the enabled artifacts under --obs-dir and report the paths."""
    if not obs.enabled:
        return {}
    out = pathlib.Path(args.obs_dir)
    written = obs.export(
        trace_jsonl=out / "run_trace.jsonl" if args.trace else None,
        chrome_trace=out / "run_trace.chrome.json" if args.trace else None,
        metrics_csv=out / "run_metrics.csv" if args.metrics else None,
        health_json=(out / "health.json" if obs.health is not None
                     else None),
        schedule=args.schedule, engine=args.engine, codec=args.codec)
    if args.metrics and hist is not None:
        written["history_json"] = write_history_json(
            hist, out / "run_history.json", schedule=args.schedule,
            engine=args.engine, codec=args.codec)
    for kind, path in sorted(written.items()):
        print(f"obs: wrote {kind} -> {path}")
    return written


def train_vit(args):
    key = jax.random.PRNGKey(args.seed)
    cfg = reduced(load_arch("vit-tiny"), num_layers=args.layers,
                  d_model=args.d_model,
                  num_heads=4, num_kv_heads=4, d_ff=2 * args.d_model)
    ssl_cfg = SSLConfig(proj_hidden=256, pred_hidden=256, proj_dim=64)
    fl = FLConfig(num_clients=args.clients, rounds=args.rounds,
                  local_epochs=args.local_epochs, schedule=args.schedule,
                  server_epochs=1, depth_dropout=args.depth_dropout,
                  clients_per_round=args.clients_per_round)
    tc = TrainConfig(batch_size=args.batch, base_lr=1.5e-4)
    kd, key = jax.random.split(key)
    images, labels = synthetic_images(kd, args.samples, 10, 32)
    if args.dirichlet_beta > 0:
        idx = dirichlet_partition(jax.device_get(labels), fl.num_clients,
                                  args.dirichlet_beta, seed=args.seed)
    else:
        idx = iid_partition(args.samples, fl.num_clients, seed=args.seed)
    aux = images[:max(args.batch, args.samples // 10)]
    sim = make_sim_from_args(args, fl.num_clients)
    obs = obs_from_args(args, "vit")
    t0 = time.time()
    with ConsoleRenderer(live=args.live) as log:
        state, hist = run_fedssl(
            cfg, ssl_cfg, fl, tc, images=images,
            client_indices=[jnp.asarray(i) for i in idx], aux_images=aux,
            key=key, log=log, engine=args.engine, codec=args.codec,
            transport_kernels=args.transport_kernels, sim=sim, obs=obs,
            privacy=privacy_from_args(args))
    export_obs(obs, args, hist=hist)
    print(f"training done in {time.time() - t0:.1f}s; "
          f"total comm {hist.total_comm / 1e6:.2f} MB analytic, "
          f"{hist.total_wire / 1e6:.2f} MB on the wire "
          f"({args.codec}: {hist.compression_ratio:.2f}x)")
    if hist.epsilon:
        print(f"privacy: eps {hist.epsilon[-1]:.4g} at delta "
              f"{args.dp_delta:g} after {len(hist.epsilon)} rounds; "
              f"mean clip fraction {np.mean(hist.clip_fraction):.2f}; "
              f"secure-agg overhead "
              f"{sum(hist.secure_agg_overhead_bytes) / 1e6:.2f} MB/client")
    if sim is not None:
        print(f"simulated fleet '{args.fleet}' / policy "
              f"'{args.round_policy}': {hist.total_wall_clock:.1f}s "
              f"wall-clock, {hist.total_device_seconds:.1f} device-s, "
              f"{hist.total_energy:.1f}J, "
              f"{hist.total_dropped} dropped client-rounds")
    enc = ssl_mod.make_vit_encoder(cfg)
    n_eval = min(args.samples // 2, 512)
    acc = fl_eval.linear_eval(
        enc, state["online"]["enc"], images[:n_eval], labels[:n_eval],
        images[n_eval:2 * n_eval], labels[n_eval:2 * n_eval],
        num_classes=10, epochs=5, batch_size=64)
    print(f"linear evaluation accuracy: {acc * 100:.2f}%")
    return acc


def train_lm(args):
    """LM-family layer-wise FedSSL on token shards (reduced arch)."""
    from repro.core.ssl import lm_ssl_loss
    from repro.models import lm as lm_mod

    key = jax.random.PRNGKey(args.seed)
    prv = make_privacy(privacy_from_args(args))
    # dedicated privacy stream: fold_in leaves the main chain untouched,
    # so DP-off runs are byte-identical to pre-privacy behavior
    k_priv = PrivacyEngine.fork_stream(key) if prv is not None else None
    cfg = reduced(load_arch(args.arch))
    S = lm_mod.num_stages(cfg)
    fl = FLConfig(num_clients=args.clients, rounds=args.rounds,
                  local_epochs=args.local_epochs, schedule=args.schedule)
    tc = TrainConfig(batch_size=args.batch, base_lr=3e-4)
    plans = sched.build_schedule(fl, S)
    opt = make_optimizer(tc)
    kd, ki, key = jax.random.split(key, 3)
    toks, labs = synthetic_tokens(kd, args.samples, args.seq_len,
                                  cfg.vocab_size)
    shards = iid_partition(args.samples, fl.num_clients, seed=args.seed)
    params = lm_mod.init_lm(ki, cfg)
    base_lr = scaled_base_lr(tc.base_lr, tc.batch_size)

    step_cache = {}

    def get_step(plan):
        sig = (plan.sub_layers, plan.active_from, plan.align)
        if sig not in step_cache:
            @jax.jit
            def train_step(params, opt_state, batch, global_params, lr):
                def loss_fn(p):
                    return lm_ssl_loss(
                        p, batch, cfg, sub_layers=sig[0], active_from=sig[1],
                        global_params=global_params if sig[2] else None,
                        align_weight=0.01 if sig[2] else 0.0)
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
                from repro.federated.masks import stage_update_mask
                mask = stage_update_mask(params, sig[0], sig[1])
                p2, o2 = opt.update(g, opt_state, params, lr, mask)
                return p2, o2, m
            step_cache[sig] = train_step
        return step_cache[sig]

    w = aggregate.client_weights([len(shards[i])
                                  for i in range(fl.num_clients)])

    def batch_start(ix, b):
        """Shard-local start of local step ``b`` — the single source of
        truth for batch selection, shared by both engines."""
        return (b * tc.batch_size) % max(1, len(ix) - tc.batch_size)

    use_vmap = args.engine == "vmap"
    obs = obs_from_args(args, "lm")
    wire = transport_mod.Transport(args.codec,
                                   kernels=args.transport_kernels, obs=obs,
                                   privacy=prv)
    all_clients = list(range(fl.num_clients))
    secure = prv is not None and prv.cfg.secure_agg
    if use_vmap:
        from repro.data.partition import stack_shards
        from repro.launch.steps import make_fl_round_program
        if min(len(s) for s in shards) < tc.batch_size:
            raise SystemExit("--engine vmap needs every shard >= batch size")
        stacked, _ = stack_shards({"tokens": toks, "labels": labs},
                                  [jnp.asarray(s) for s in shards])
        nbs = [max(1, len(s) // tc.batch_size) for s in shards]
        T = max(nbs) * fl.local_epochs
        # replay the sequential loop's deterministic batch slices as
        # shard-local gather indices; ragged clients are masked out
        batch_idx = np.zeros((fl.num_clients, T, tc.batch_size), np.int32)
        valid = np.zeros((fl.num_clients, T), bool)
        for ci, ix in enumerate(shards):
            for b in range(nbs[ci] * fl.local_epochs):
                start = batch_start(ix, b)
                batch_idx[ci, b] = np.arange(start, start + tc.batch_size)
                valid[ci, b] = True
        batch_idx, valid = jnp.asarray(batch_idx), jnp.asarray(valid)
        step_keys = jnp.zeros((fl.num_clients, T, 2), jnp.uint32)
        round_cache = {}

        def get_round(plan, spec, fedavg=True):
            sig = (plan.sub_layers, plan.active_from, plan.align, spec.sig,
                   fedavg)
            if sig not in round_cache:
                wt = wire.make_wire_transform(spec)
                round_cache[sig] = make_fl_round_program(
                    cfg, tc, sub_layers=plan.sub_layers,
                    active_from=plan.active_from, align=plan.align,
                    wire_transform=lambda outs, bc, res: wt(
                        outs, bc["server"], bc["params"], res),
                    fedavg=fedavg)[0]
            return round_cache[sig]

    hist = []
    wire_mb = 0.0
    tracer, log = obs.tracer, ConsoleRenderer(live=args.live)
    obs.start_profiler()
    with tracer.span("run", cat="fl", mode="lm-fedssl",
                     schedule=fl.schedule, engine=args.engine,
                     codec=wire.codec.name, kernels=args.transport_kernels,
                     rounds=fl.rounds, clients=fl.num_clients):
        for plan in plans:
            round_span = tracer.span("round", cat="fl",
                                     round=plan.round_idx, stage=plan.stage)
            t_round = time.perf_counter()
            with round_span:
                if plan.new_stage and fl.weight_transfer:
                    params = sched.transfer_model(params, cfg, plan.stage)
                lr = float(learning_rate(plan.round_idx, fl.rounds, base_lr,
                                         tc.lr_schedule))
                # both directions route through the wire transport: clients
                # train from the decoded broadcast, FedAvg consumes decoded
                # uploads
                dparams, down = wire.broadcast(params, plan)
                global_params = (jax.tree.map(jnp.copy, dparams)
                                 if plan.align else None)
                train_span = tracer.span("local_train", cat="fl",
                                         engine=args.engine,
                                         clients=fl.num_clients)
                spec = (wire.plan_specs(params, plan)["upload"]
                        if (use_vmap or prv is not None) else None)
                if prv is not None:
                    k_noise, mask_seed = PrivacyEngine.round_keys(
                        k_priv, plan.round_idx)
                if use_vmap:
                    up = dict(wire.upload_stats(spec))
                    res = wire.gather_residuals(all_clients, spec)
                    with train_span:
                        result, lvec, new_res, scales = get_round(
                            plan, spec, fedavg=not secure)(
                            {"params": dparams,
                             "global_params": global_params,
                             "server": params},
                            stacked, batch_idx, step_keys, valid, w,
                            jnp.float32(lr), res)
                    wire.store_residuals(all_clients, spec, new_res)
                    if secure:
                        # unstack the decoded client axis and FedAvg
                        # through the masked fixed-point pipeline
                        trees = [jax.tree.map(lambda a, i=i: a[i], result)
                                 for i in range(fl.num_clients)]
                        params = prv.secure_fedavg(
                            trees, np.asarray(w), all_clients, spec=spec,
                            transport=wire, base=params, seed=mask_seed)
                    else:
                        params = result
                    up["clip_fraction"] = float(
                        np.mean(np.asarray(scales, np.float32) < 1.0))
                    losses = [float(x) for x in np.asarray(lvec)]
                else:
                    step = get_step(plan)
                    outs, losses = [], []
                    with train_span:
                        for ci in range(fl.num_clients):
                            p_i = jax.tree.map(jnp.asarray, dparams)
                            o_i = opt.init(p_i)
                            ix = shards[ci]
                            nb = max(1, len(ix) // tc.batch_size)
                            for b in range(nb * fl.local_epochs):
                                sel = ix[batch_start(ix, b):][:tc.batch_size]
                                batch = {"tokens": toks[sel],
                                         "labels": labs[sel]}
                                p_i, o_i, m = step(p_i, o_i, batch,
                                                   global_params,
                                                   jnp.float32(lr))
                            outs.append(p_i)
                            losses.append(float(m["loss"]))
                    if secure:
                        trees, up = wire.decode_uploads(
                            params, outs, all_clients, plan,
                            ref_online=dparams)
                        params = prv.secure_fedavg(
                            trees, np.asarray(w), all_clients, spec=spec,
                            transport=wire, base=params, seed=mask_seed)
                    else:
                        params, up = wire.aggregate_uploads(
                            params, outs, all_clients, plan, w,
                            ref_online=dparams)
                eps = None
                if prv is not None:
                    if prv.noise_enabled:
                        params = prv.add_noise(
                            params, spec, wire, k_noise,
                            prv.sigma(float(np.max(np.asarray(w)))))
                    # full participation every round: q = 1
                    prv.accountant.observe_round(1.0)
                    eps = float(prv.accountant.epsilon(prv.cfg.delta))
                wire_mb += (down["wire_bytes"] + up["wire_bytes"]) / 1e6
                hist.append(sum(losses) / len(losses))
                cb = comm.round_comm_bytes(params, plan)
                round_span.set(loss=hist[-1], lr=lr,
                               download_bytes=cb["download"],
                               upload_bytes=cb["upload"],
                               wire_download_bytes=down["wire_bytes"],
                               wire_upload_bytes=up["wire_bytes"])
                if prv is not None:
                    round_span.set(
                        epsilon=eps,
                        clip_fraction=float(up.get("clip_fraction", 0.0)),
                        secure_agg_overhead_bytes=prv.secure_overhead_bytes(
                            spec, wire.wire_bytes(spec)))
            if obs.enabled:
                met = obs.metrics
                met.counter("fl.rounds").inc()
                met.counter("comm.download_bytes").inc(cb["download"])
                met.counter("comm.upload_bytes").inc(cb["upload"])
                met.counter("wire.download_bytes").inc(down["wire_bytes"])
                met.counter("wire.upload_bytes").inc(up["wire_bytes"])
                met.histogram("round.loss").observe(hist[-1])
                met.histogram("round.host_seconds").observe(
                    time.perf_counter() - t_round)
            log(format_round_line(
                plan.round_idx, fl.rounds, plan.stage, hist[-1], lr=lr,
                wire_mb=(down["wire_bytes"] + up["wire_bytes"]) / 1e6,
                extra=f" eps {eps:.3g}" if prv is not None
                and prv.dp else ""))
            if obs.health is not None:
                for alert in obs.health.observe_round(
                        plan.round_idx, loss=hist[-1],
                        compression_ratio=(cb["download"] + cb["upload"])
                        / max(1, down["wire_bytes"] + up["wire_bytes"]),
                        participants=fl.num_clients,
                        new_stage=plan.new_stage):
                    tracer.instant("health." + alert.kind, cat="health",
                                   level=alert.level, round=plan.round_idx,
                                   message=alert.message)
                    log(f"health[{alert.level}] round {plan.round_idx}: "
                        f"{alert.message}")
                if obs.health.should_halt:
                    tracer.instant("health.halt", cat="health",
                                   round=plan.round_idx)
                    log(f"health: fatal alert; halting after round "
                        f"{plan.round_idx + 1}/{fl.rounds}")
                    break
            if (prv is not None and prv.cfg.epsilon_budget > 0.0
                    and eps > prv.cfg.epsilon_budget):
                log(f"privacy budget exhausted: eps {eps:.4g} > "
                    f"{prv.cfg.epsilon_budget:.4g} after round "
                    f"{plan.round_idx + 1}/{fl.rounds}; halting")
                break
    obs.stop_profiler()
    log.close()
    export_obs(obs, args)
    print(f"final loss {hist[-1]:.4f} (start {hist[0]:.4f}); "
          f"{wire_mb:.2f} MB/client on the wire ({args.codec})")
    if prv is not None and prv.dp:
        print(f"privacy: eps {eps:.4g} at delta {prv.cfg.delta:g} "
              f"after {len(hist)} rounds")
    return params, hist


def make_sim_from_args(args, num_clients):
    """Build the fleet simulator from CLI flags; None when --fleet unset."""
    if not args.fleet:
        if args.round_policy != "synchronous":
            raise SystemExit(
                "--round-policy needs --fleet (one of "
                + ", ".join(fleet_mod.PROFILES) + ")")
        return None
    kw = {}
    if args.round_policy == "deadline":
        kw = {"overcommit": args.overcommit}
        if args.deadline_s > 0:
            kw["deadline_s"] = args.deadline_s
    elif args.round_policy == "buffered-async":
        kw = {"buffer": args.async_buffer, "alpha": args.staleness_alpha}
    return sim_mod.make_sim(args.fleet, args.round_policy,
                            num_clients=num_clients, seed=args.seed, **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("vit", "lm"), default="vit")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--schedule", default="lw_fedssl",
                    choices=sched.SCHEDULES)
    ap.add_argument("--engine", default="sequential",
                    choices=("sequential", "vmap"),
                    help="round engine: per-client loop (reference) or "
                         "one jit'd vmapped program per round")
    ap.add_argument("--codec", default="fp32",
                    help="wire compression codec for downloads/uploads: "
                         "fp32 (identity), fp16, bf16, int8 (per-channel "
                         "quantization), topk[:frac] (sparsification with "
                         "error feedback, e.g. topk:0.05)")
    ap.add_argument("--transport-kernels", default="xla",
                    choices=transport_mod.TRANSPORT_KERNELS,
                    help="wire-path engine: xla (jit'd slice/concat "
                         "reference) or pallas (fused pack/codec kernels "
                         "— docs/kernels.md)")
    ap.add_argument("--fleet", default="",
                    choices=("",) + fleet_mod.PROFILES,
                    help="simulate a heterogeneous device fleet drawn from "
                         "this named profile (docs/simulation.md); empty = "
                         "no simulation")
    ap.add_argument("--round-policy", default="synchronous",
                    choices=sim_mod.POLICIES,
                    help="round scheduling policy over the simulated "
                         "fleet: synchronous (wait for all), deadline "
                         "(overcommit + drop stragglers), buffered-async "
                         "(staleness-weighted FedBuff aggregation)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="fixed round deadline in simulated seconds "
                         "(0 = adaptive: the cohort's 60th percentile)")
    ap.add_argument("--overcommit", type=float, default=1.5,
                    help="deadline policy: sample this factor more "
                         "clients, clamped to the population")
    ap.add_argument("--async-buffer", type=int, default=0,
                    help="buffered-async: aggregate once this many "
                         "updates arrived (0 = half the cohort)")
    ap.add_argument("--staleness-alpha", type=float, default=0.5,
                    help="buffered-async: (1+staleness)^-alpha weight "
                         "discount")
    ap.add_argument("--dp-clip", type=float, default=0.0,
                    help="client-level DP: L2 clip on each client's "
                         "stage-payload update (0 = off; 'inf' runs the "
                         "clipping machinery as an exact pass-through)")
    ap.add_argument("--dp-noise-multiplier", type=float, default=0.0,
                    help="client-level DP: noise multiplier z — server "
                         "adds N(0, (z*clip*max_w)^2) to the aggregate; "
                         "requires a finite --dp-clip > 0")
    ap.add_argument("--dp-delta", type=float, default=1e-5,
                    help="delta of the reported (eps, delta) guarantee")
    ap.add_argument("--dp-epsilon-budget", type=float, default=0.0,
                    help="halt training once cumulative eps exceeds this "
                         "(0 = unlimited)")
    ap.add_argument("--secure-agg", action="store_true",
                    help="pairwise-mask secure aggregation: FedAvg runs "
                         "as a masked fixed-point sum, the server never "
                         "sees an individual update (docs/privacy.md)")
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--clients-per-round", type=int, default=0)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--samples", type=int, default=1024)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--depth-dropout", type=float, default=0.0)
    ap.add_argument("--dirichlet-beta", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", action="store_true",
                    help="record a span trace of the run and write "
                         "run_trace.jsonl + run_trace.chrome.json (the "
                         "latter loads in Perfetto / chrome://tracing) "
                         "under --obs-dir; analyze with `python -m "
                         "repro.launch.trace` (docs/observability.md)")
    ap.add_argument("--metrics", action="store_true",
                    help="record typed counters/gauges/histograms and "
                         "write run_metrics.csv + run_history.json under "
                         "--obs-dir")
    ap.add_argument("--health", action="store_true",
                    help="attach the streaming health monitor (NaN/inf "
                         "loss, z-score loss spikes, compression-ratio "
                         "and straggler drop-rate drift, jit-recompile "
                         "storms) and write a schema-validated "
                         "health.json under --obs-dir "
                         "(docs/observability.md)")
    ap.add_argument("--halt-on-unhealthy", action="store_true",
                    help="stop training on a fatal health alert "
                         "(implies --health)")
    ap.add_argument("--measure-resources", action="store_true",
                    help="AOT-lower each new stage's round program and "
                         "attach measured cost_analysis attributes "
                         "(res.*) to the stage-opening round span; a few "
                         "seconds per stage")
    ap.add_argument("--profile-dir", default="",
                    help="also capture a jax.profiler (XLA-level) trace "
                         "into this directory; spans are host-level")
    ap.add_argument("--obs-dir", default="results",
                    help="directory for observability artifacts")
    ap.add_argument("--live", action="store_true",
                    help="render round progress as a single live-updating "
                         "console line instead of one line per round")
    args = ap.parse_args()
    try:
        transport_mod.make_codec(args.codec)
        make_privacy(privacy_from_args(args))
    except ValueError as e:
        ap.error(str(e))
    if args.mode == "lm" and args.fleet:
        ap.error("--fleet simulation currently drives the vit driver "
                 "(repro.federated.driver); use --mode vit")
    if args.mode == "vit":
        train_vit(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
