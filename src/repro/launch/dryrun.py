import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")   # silence SPMD warnings

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.
_DOC = """Multi-pod dry-run.

Proves the distribution config is coherent without hardware: a successful
``.lower().compile()`` on the 512-way host-platform mesh means every
sharding constraint, collective and memory layout resolves. Prints
``memory_analysis()`` (fits-per-device proof) and ``cost_analysis()``
(FLOPs/bytes for the roofline), and appends JSON rows consumed by
EXPERIMENTS.md / benchmarks.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all 40 pairs
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k --multi-pod --mode train_lw
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, INPUT_SHAPES
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import scan_cfg
from repro.roofline import analyze_compiled, roofline_report

# Unrolled layer scans => cost_analysis sees every layer (see scan_cfg).
# The multi-pod coherence pass uses --rolled: sharding/collective validity
# does not depend on unrolling, and compiles are ~10x faster.
scan_cfg.UNROLL = True

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"
TABLE_ARCHS = [a for a in ARCH_IDS if a != "vit-tiny"]


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            mode: str = None, out_rows: list = None, verbose: bool = True,
            cfg_override=None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    step, args, cfg, train_cfg = input_specs(arch, shape_name, mesh,
                                             mode=mode,
                                             cfg_override=cfg_override)
    mode = mode or INPUT_SHAPES[shape_name].kind
    mode_eff = mode or INPUT_SHAPES[shape_name].kind
    donate = ()
    if mode_eff in ("train", "train_lw"):
        donate = (0, 1)       # params, opt_state update in place
    elif mode_eff == "decode":
        donate = (1,)         # KV cache / recurrent state ring buffers
    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    micro = train_cfg.microbatch if mode in ("train", "train_lw") else 0
    res = analyze_compiled(
        compiled, arch=arch, shape=shape_name, mode=mode,
        mesh_name=mesh_name, n_devices=mesh.size, cfg=cfg,
        shape_cfg=INPUT_SHAPES[shape_name],
        cost_scale=float(micro) if micro and micro > 1 else 1.0)
    row = res.to_dict()
    row["compile_s"] = time.time() - t0
    if verbose:
        print(roofline_report(res), f" [compile {row['compile_s']:.0f}s]",
              flush=True)
    if out_rows is not None:
        out_rows.append(row)
    return row


def run_one_extrapolated(arch: str, shape_name: str, *, mode: str = None,
                         out_rows: list = None):
    """Depth-extrapolated roofline for archs whose fully-unrolled train
    graph is intractable to compile on one CPU core (zamba-class: L layers
    x rolled chunk loop x backward).

    Lower g and 2g stage-groups unrolled; per-device flops/bytes/collective
    are affine in depth, so row(L) = row(g) + (L-g)/g * (row(2g) - row(g)).
    model_flops / memory footprint are reported for the FULL config (memory
    from the rolled full-depth compile, which does succeed — the multi-pod
    pass proves it). Rows are tagged method="depth-extrapolated".
    """
    import dataclasses
    cfg = __import__("repro.configs.base", fromlist=["load_arch"]) \
        .load_arch(arch)
    if cfg.attn_every:
        g = cfg.attn_every
        mk = lambda n: dataclasses.replace(cfg, num_layers=n)   # noqa: E731
    elif cfg.xlstm is not None and cfg.xlstm.slstm_every:
        g = cfg.xlstm.slstm_every
        mk = lambda n: dataclasses.replace(cfg, num_layers=n)   # noqa: E731
    else:
        g = max(1, cfg.num_layers // 8)
        mk = lambda n: dataclasses.replace(cfg, num_layers=n)   # noqa: E731
    L = cfg.num_layers
    r1 = run_one(arch, shape_name, mode=mode, cfg_override=mk(g),
                 verbose=False)
    r2 = run_one(arch, shape_name, mode=mode, cfg_override=mk(2 * g),
                 verbose=False)
    # full-depth rolled compile for the true memory footprint
    scan_cfg.UNROLL = False
    try:
        r_full = run_one(arch, shape_name, mode=mode, verbose=False)
    finally:
        scan_cfg.UNROLL = True
    k = (L - g) / float(g)
    row = dict(r_full)
    for key in ("flops_dev", "bytes_dev", "coll_bytes_dev"):
        if r2[key] > r1[key]:
            row[key] = r1[key] + k * (r2[key] - r1[key])
        else:
            # fusion noise can make the 2g measurement dip below g; fall
            # back to proportional scaling of the larger measurement
            row[key] = r2[key] * (L / float(2 * g))
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    row["compute_s"] = row["flops_dev"] / PEAK_FLOPS_BF16
    row["memory_s"] = row["bytes_dev"] / HBM_BW
    row["collective_s"] = row["coll_bytes_dev"] / ICI_BW
    terms = {"compute": row["compute_s"], "memory": row["memory_s"],
             "collective": row["collective_s"]}
    row["dominant"] = max(terms, key=terms.get)
    row["useful_ratio"] = row["model_flops_total"] / max(
        row["flops_dev"] * row["n_devices"], 1e-9)
    row["method"] = "depth-extrapolated"
    print(f"{arch:28s} {shape_name:12s} {row['mode']:9s} {row['mesh']:9s} "
          f"comp {row['compute_s']*1e3:9.3f}ms  "
          f"mem {row['memory_s']*1e3:9.3f}ms  "
          f"coll {row['collective_s']*1e3:9.3f}ms  "
          f"-> {row['dominant']:10s} useful {row['useful_ratio']*100:5.1f}% "
          f"[extrapolated {g}->{2*g}->{L}]", flush=True)
    if out_rows is not None:
        out_rows.append(row)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mode", default=None,
                    help="train|train_lw|prefill|decode (default: by shape)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None, help="JSON output path")
    ap.add_argument("--keep-going", action="store_true")
    ap.add_argument("--rolled", action="store_true",
                    help="keep layer scans rolled (fast compile; roofline "
                         "flops under-counted — coherence checking only)")
    ap.add_argument("--extrapolate", action="store_true",
                    help="depth-extrapolated roofline (see "
                         "run_one_extrapolated)")
    args = ap.parse_args()
    if args.rolled:
        scan_cfg.UNROLL = False

    archs = [args.arch] if args.arch else TABLE_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    rows, failures = [], []
    for arch in archs:
        for shape in shapes:
            try:
                if args.extrapolate:
                    run_one_extrapolated(arch, shape, mode=args.mode,
                                         out_rows=rows)
                else:
                    run_one(arch, shape, multi_pod=args.multi_pod,
                            mode=args.mode, out_rows=rows)
            except Exception as e:                      # noqa: BLE001
                failures.append((arch, shape, repr(e)))
                print(f"FAIL {arch} {shape}: {e}", flush=True)
                if not args.keep_going:
                    traceback.print_exc()
                    raise
    if args.out:
        out = pathlib.Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(rows, indent=1))
        print(f"wrote {len(rows)} rows -> {out}")
    if failures:
        print(f"{len(failures)} failures:", *failures, sep="\n  ")
        raise SystemExit(1)
    print(f"DRY-RUN OK: {len(rows)} combinations lowered + compiled")


if __name__ == "__main__":
    main()
