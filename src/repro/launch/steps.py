"""Step functions lowered by the dry-run / launchers, per (arch, mode).

Modes
  train      — end-to-end local SSL train step (paper baseline FedMoCo):
               next-token loss, grads, optimizer update.
  train_lw   — LW-FedSSL local step at the *final* stage (full-depth
               forward, only L_S trained, representation alignment against
               the broadcast global model) — the paper's technique.
  prefill    — full-prompt forward, last-position logits.
  decode     — one-token serve step against a KV cache of seq_len.

All steps are pure jit-able functions over (params, opt_state, batch, ...)
pytrees; gradient accumulation (``train_cfg.microbatch``) runs as a
``lax.scan`` over microbatch slices so only one microbatch's activations
are ever live.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.ssl import lm_ssl_loss
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.optim import make_optimizer
from repro.federated.masks import stage_update_mask

ALIGN_WEIGHT = 0.01
TAU = 0.2


def cfg_for_shape(cfg, shape_name: str):
    """long_500k: quadratic-attention archs switch to sliding window 8192.

    SSM/hybrid run natively; DeepSeek's MLA keeps the full-context latent
    cache (the compressed cache is the point of MLA — see DESIGN.md).
    """
    if shape_name == "long_500k" and cfg.window == 0 and cfg.mla is None \
            and cfg.family in ("dense", "vlm", "audio", "moe"):
        return dataclasses.replace(cfg, window=8192)
    return cfg


def is_encdec(cfg) -> bool:
    return bool(cfg.cross_attention and cfg.dec_layers)


# ---------------------------------------------------------------------------
# training steps
# ---------------------------------------------------------------------------
def _loss_for(cfg, params, batch, *, sub_layers, active_from, global_params,
              align_weight, remat):
    if is_encdec(cfg):
        loss, metrics = encdec_mod.encdec_loss(
            params, batch, cfg, sub_layers=sub_layers,
            active_from=active_from, remat=remat)
        if align_weight and global_params is not None:
            # Eq. 3 alignment on mean-pooled encoder memory
            from repro.core.losses import info_nce
            mem = encdec_mod.encode(params, batch["frontend"], cfg,
                                    sub_layers=sub_layers,
                                    active_from=active_from, remat=remat)
            gmem = encdec_mod.encode(global_params, batch["frontend"], cfg,
                                     sub_layers=sub_layers, active_from=0,
                                     remat=remat)
            z = jnp.mean(mem.astype(jnp.float32), axis=1)
            zg = jax.lax.stop_gradient(
                jnp.mean(gmem.astype(jnp.float32), axis=1))
            la = info_nce(z, zg, TAU)
            loss = loss + align_weight * la
            metrics = {**metrics, "align": la}
        return loss, metrics
    return lm_ssl_loss(params, batch, cfg, sub_layers=sub_layers,
                       active_from=active_from, global_params=global_params,
                       align_weight=align_weight, tau=TAU, remat=remat)


def make_train_step(cfg, train_cfg, *, mode: str = "train", lr: float = 1e-4):
    """Returns step(params, opt_state, batch[, global_params]) ->
    (params, opt_state, metrics)."""
    opt = make_optimizer(train_cfg)
    S = lm_mod.num_stages(cfg) if not is_encdec(cfg) else cfg.num_layers
    lw = mode == "train_lw"
    sub_layers = S
    active_from = S - 1 if lw else 0
    align_weight = ALIGN_WEIGHT if lw else 0.0
    remat = train_cfg.remat
    micro = train_cfg.microbatch

    def grads_of(params, batch, global_params):
        def loss_fn(p):
            return _loss_for(cfg, p, batch, sub_layers=sub_layers,
                             active_from=active_from,
                             global_params=global_params,
                             align_weight=align_weight, remat=remat)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def step(params, opt_state, batch, global_params=None):
        if micro and micro > 1:
            def slice_mb(i, t):
                def f(a):
                    mb = a.shape[0] // micro
                    return jax.lax.dynamic_slice_in_dim(a, i * mb, mb, 0)
                return jax.tree.map(f, t)

            def body(carry, i):
                acc, lsum = carry
                (l, _), g = grads_of(params, slice_mb(i, batch),
                                     global_params)
                acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, g)
                return (acc, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0.0)), jnp.arange(micro))
            grads = jax.tree.map(lambda g: g / micro, grads)
            metrics = {"loss": lsum / micro}
        else:
            (loss, m), grads = grads_of(params, batch, global_params)
            metrics = {"loss": loss, **m}
        mask = (stage_update_mask(params, sub_layers, active_from)
                if lw else None)
        new_params, new_opt = opt.update(grads, opt_state, params,
                                         jnp.float32(lr), mask)
        return new_params, new_opt, metrics

    return step, opt


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------
def make_prefill_step(cfg):
    if is_encdec(cfg):
        def step(params, frames, tokens):
            logits, _ = encdec_mod.prefill(params, frames, tokens, cfg)
            return logits
        return step

    def step(params, batch):
        logits, _ = lm_mod.prefill(params, batch["tokens"], cfg,
                                   batch.get("frontend"))
        return logits
    return step


def make_decode_step(cfg):
    if is_encdec(cfg):
        def step(params, caches, token, pos, memory):
            return encdec_mod.decode_step(params, caches, token, pos,
                                          memory, cfg)
        return step

    def step(params, caches, token, pos):
        return lm_mod.decode_step(params, caches, token, pos, cfg)
    return step


# ---------------------------------------------------------------------------
# vectorized multi-client round (LM FedSSL)
# ---------------------------------------------------------------------------
def make_fl_round_program(cfg, train_cfg, *, mode: str = "train",
                          sub_layers: int = None, active_from: int = None,
                          align: bool = None, wire_transform=None,
                          fedavg: bool = True):
    """One jit'd program for an entire LM FL round: every sampled client's
    local steps run as a ``lax.scan`` vmapped over the client axis, with
    FedAvg fused at the end (``repro.federated.engine`` semantics).

    Stage defaults follow ``mode`` (end-to-end for ``train``, final-stage
    + alignment for ``train_lw``); stage schedules override
    ``sub_layers`` / ``active_from`` / ``align`` per ``RoundPlan``.

    Returns ``(round_fn, opt)``; ``round_fn(broadcast, shards, batch_idx,
    step_keys, valid, weights, lr)`` where ``broadcast`` holds ``params``
    (and ``global_params`` when aligning) and every ``shards`` leaf is
    ``(C, n_max, ...)``. Unlike ``make_train_step``, the ``lr`` argument
    is live — each round can pass its scheduled learning rate.

    ``wire_transform`` (optional) is the transport hook forwarded to
    ``build_round_program``: client results are wire-encoded/decoded
    (DP-clipped first when the transport carries a privacy engine) before
    the fused FedAvg, and the program takes a trailing ``residuals``
    argument and returns updated residuals plus per-client clip scales
    (see ``repro.federated.transport``). ``fedavg=False`` returns the
    decoded client-stacked trees instead of their FedAvg — secure
    aggregation masks and averages them outside the program.
    """
    from repro.federated.engine import build_round_program

    opt = make_optimizer(train_cfg)
    S = lm_mod.num_stages(cfg) if not is_encdec(cfg) else cfg.num_layers
    lw = mode == "train_lw"
    if sub_layers is None:
        sub_layers = S
    if active_from is None:
        active_from = S - 1 if lw else 0
    if align is None:
        align = lw
    align_weight = ALIGN_WEIGHT if align else 0.0
    remat = train_cfg.remat

    def step(params, opt_state, batch, global_params, lr):
        def loss_fn(p):
            return _loss_for(cfg, p, batch, sub_layers=sub_layers,
                             active_from=active_from,
                             global_params=global_params if align else None,
                             align_weight=align_weight, remat=remat)

        (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        mask = (stage_update_mask(params, sub_layers, active_from)
                if (active_from > 0 or sub_layers < S) else None)
        new_params, new_opt = opt.update(grads, opt_state, params, lr, mask)
        return new_params, new_opt, {"loss": loss, **m}

    def client_init(bc):
        p = jax.tree.map(jnp.asarray, bc["params"])
        return p, opt.init(p)

    def client_step(carry, batch, key, lr, bc):
        p, o = carry
        p, o, m = step(p, o, batch, bc.get("global_params"), lr)
        return (p, o), m["loss"]

    return build_round_program(client_init, client_step, lambda c: c[0],
                               wire_transform=wire_transform,
                               fedavg=fedavg), opt
