"""Production mesh definitions (TPU v5e).

Single pod: 256 chips as (16, 16) = ("data", "model").
Multi-pod:  2 pods x 256 chips as (2, 16, 16) = ("pod", "data", "model").

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests run with the
single real CPU device).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip), used by repro.roofline
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
